//! `serve::chaos` — deterministic fault injection for the serving tier.
//!
//! The serving stack has a typed failure surface (every bad outcome is a
//! [`super::ServeError`] or a typed wire code) and recovery machinery
//! (panic isolation per batch, poison-recovering locks, the retrying
//! [`super::net::RetryClient`], the fleet's rung supervisor).  This
//! module *exercises* all of it on purpose, reproducibly:
//!
//! * [`FaultPlan`] — one deterministic decision stream, driven by the
//!   repo's seeded [`Rng`].  Seed it explicitly or from the
//!   `LM_CHAOS_SEED` environment variable so a failing soak run replays
//!   bit-identically.  Two modes: random faults at configured rates, or
//!   a fault pinned to exactly the Nth event (the generalization of the
//!   ad-hoc "panic on batch 2" mocks in `tests/serve_net.rs`).
//! * [`FaultBackend`] — a [`Backend`] decorator that fails, delays, or
//!   panics `run` dispatches on the plan's schedule while delegating
//!   everything else (uploads keep their packed layouts, transfer
//!   counters stay honest).
//! * [`wrap_fn`] — the same injection at the session-dispatch layer, for
//!   `Session::from_fn` / `Fleet::deploy_fn` mocks.
//! * [`FaultProxy`] — a loopback TCP proxy that drops, stalls,
//!   truncates, or byte-corrupts request frames *before* forwarding, so
//!   every injected wire fault is retry-safe by construction (a faulted
//!   request never reached the server).
//!
//! Everything is deterministic given a seed **except** wall-clock
//! interleaving — the decision streams (which events fault, which bytes
//! corrupt) replay exactly; thread scheduling around them does not.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::plock;
use crate::runtime::{Backend, OpDesc, OpHandle, Value};
use crate::util::rng::{seed_from_env, Rng};
use crate::util::tensor::Tensor;

/// The environment variable chaos runs take their seed from.
pub const CHAOS_SEED_ENV: &str = "LM_CHAOS_SEED";

/// The seed for this chaos run: `LM_CHAOS_SEED` (decimal or `0x` hex)
/// when set, else `default`.
pub fn env_seed(default: u64) -> u64 {
    seed_from_env(CHAOS_SEED_ENV, default)
}

// ---------------------------------------------------------------------------
// FaultPlan
// ---------------------------------------------------------------------------

/// One injected fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// The dispatch returns an error (`BackendFailed` downstream).
    Fail,
    /// The dispatch panics (must be caught by the batch isolation).
    Panic,
    /// The dispatch is delayed by this much before running normally.
    Delay(Duration),
}

/// Per-event fault rates for [`FaultPlan::random`].  Rates are
/// probabilities in `[0, 1]` and are applied disjointly, in order
/// (`fail`, then `panic`, then `delay`), from a single uniform draw per
/// event — so `fail + panic + delay` must be ≤ 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Probability an event errors.
    pub fail: f64,
    /// Probability an event panics.
    pub panic: f64,
    /// Probability an event is delayed by `delay_ms`.
    pub delay: f64,
    /// Injected delay length, ms.
    pub delay_ms: u64,
}

impl FaultSpec {
    /// No faults at all (the control arm of an experiment).
    pub const NONE: FaultSpec = FaultSpec { fail: 0.0, panic: 0.0, delay: 0.0, delay_ms: 0 };

    /// Errors only, at rate `p`.
    pub fn failing(p: f64) -> FaultSpec {
        FaultSpec { fail: p, ..FaultSpec::NONE }
    }
}

enum Mode {
    /// Independent per-event draws at the spec's rates.
    Random(FaultSpec),
    /// Exactly one fault, on 0-based event `n`.
    Nth { n: u64, fault: Fault },
}

/// Monotonic injection tallies (what the plan actually did — invariant
/// suites compare these against the observed typed failures).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Events seen (faulted or not).
    pub events: usize,
    pub failed: usize,
    pub panicked: usize,
    pub delayed: usize,
}

impl FaultCounts {
    /// Total events that had a fault injected.
    pub fn injected(&self) -> usize {
        self.failed + self.panicked + self.delayed
    }
}

/// A deterministic schedule of faults: each call to [`FaultPlan::next`]
/// is one event (one backend dispatch, one session batch, one proxied
/// frame) and yields the fault to inject, if any.  Decisions come from
/// one seeded [`Rng`] stream behind a mutex, so the *sequence* of
/// decisions is reproducible even when the events race (which event gets
/// which decision then depends on scheduling — the counts and the
/// invariants do not).
pub struct FaultPlan {
    mode: Mode,
    rng: Mutex<Rng>,
    events: AtomicU64,
    failed: AtomicUsize,
    panicked: AtomicUsize,
    delayed: AtomicUsize,
}

impl FaultPlan {
    /// Random faults at the spec's rates, seeded explicitly.
    pub fn random(spec: FaultSpec, seed: u64) -> Arc<FaultPlan> {
        let total = spec.fail + spec.panic + spec.delay;
        assert!(
            (0.0..=1.0).contains(&total),
            "fault rates must sum into [0, 1], got {total}"
        );
        Arc::new(FaultPlan {
            mode: Mode::Random(spec),
            rng: Mutex::new(Rng::new(seed)),
            events: AtomicU64::new(0),
            failed: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
            delayed: AtomicUsize::new(0),
        })
    }

    /// Random faults seeded from `LM_CHAOS_SEED` (else `default_seed`).
    pub fn random_env(spec: FaultSpec, default_seed: u64) -> Arc<FaultPlan> {
        FaultPlan::random(spec, env_seed(default_seed))
    }

    /// Exactly one `fault`, injected on the 0-based `n`th event — the
    /// deterministic "error/panic/slow on the Nth dispatch" schedule the
    /// serve tests use.
    pub fn nth(n: u64, fault: Fault) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            mode: Mode::Nth { n, fault },
            rng: Mutex::new(Rng::new(0)),
            events: AtomicU64::new(0),
            failed: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
            delayed: AtomicUsize::new(0),
        })
    }

    /// A plan that never faults (control arm; keeps call sites uniform).
    pub fn none() -> Arc<FaultPlan> {
        FaultPlan::random(FaultSpec::NONE, 0)
    }

    /// Decide the fault for the next event, tallying the decision.
    pub fn next(&self) -> Option<Fault> {
        let event = self.events.fetch_add(1, Ordering::Relaxed);
        let fault = match &self.mode {
            Mode::Nth { n, fault } => (event == *n).then_some(*fault),
            Mode::Random(spec) => {
                let u = plock(&self.rng).uniform();
                if u < spec.fail {
                    Some(Fault::Fail)
                } else if u < spec.fail + spec.panic {
                    Some(Fault::Panic)
                } else if u < spec.fail + spec.panic + spec.delay {
                    Some(Fault::Delay(Duration::from_millis(spec.delay_ms)))
                } else {
                    None
                }
            }
        };
        match fault {
            Some(Fault::Fail) => drop(self.failed.fetch_add(1, Ordering::Relaxed)),
            Some(Fault::Panic) => drop(self.panicked.fetch_add(1, Ordering::Relaxed)),
            Some(Fault::Delay(_)) => drop(self.delayed.fetch_add(1, Ordering::Relaxed)),
            None => {}
        }
        fault
    }

    /// What this plan has injected so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            events: self.events.load(Ordering::Relaxed) as usize,
            failed: self.failed.load(Ordering::Relaxed),
            panicked: self.panicked.load(Ordering::Relaxed),
            delayed: self.delayed.load(Ordering::Relaxed),
        }
    }
}

/// Apply one decided fault at a dispatch site: sleep for delays, panic
/// for panics, error for failures.  Returns `Ok(())` when the dispatch
/// should proceed (possibly after a delay).
fn apply(fault: Option<Fault>, what: &str) -> Result<()> {
    match fault {
        None => Ok(()),
        Some(Fault::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(Fault::Fail) => Err(anyhow::anyhow!("chaos: injected {what} failure")),
        Some(Fault::Panic) => panic!("chaos: injected {what} panic"),
    }
}

// ---------------------------------------------------------------------------
// Backend-layer injection
// ---------------------------------------------------------------------------

/// A [`Backend`] decorator that injects the plan's faults into `run`
/// dispatches and delegates everything else untouched — uploads keep the
/// inner backend's packed weight layouts, `supports`/`lower_op` resolve
/// against the real implementation, and the transfer counters are the
/// inner backend's.  One fault event per `run` call (i.e. per lowered
/// op, not per batch — a D-step plan draws D events per forward).
pub struct FaultBackend {
    inner: Arc<dyn Backend>,
    plan: Arc<FaultPlan>,
}

impl FaultBackend {
    pub fn wrap(inner: Arc<dyn Backend>, plan: Arc<FaultPlan>) -> FaultBackend {
        FaultBackend { inner, plan }
    }

    /// The injection schedule (for asserting tallies after a run).
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl Backend for FaultBackend {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn upload(&self, t: &Tensor) -> Result<Value> {
        self.inner.upload(t)
    }

    fn upload_weight(&self, desc: &OpDesc, w: &Tensor) -> Result<Value> {
        self.inner.upload_weight(desc, w)
    }

    fn weight_format(&self) -> crate::runtime::WeightFormat {
        self.inner.weight_format()
    }

    fn download(&self, v: &Value) -> Result<Tensor> {
        self.inner.download(v)
    }

    fn supports(&self, desc: &OpDesc) -> bool {
        self.inner.supports(desc)
    }

    fn lower_op(&self, desc: &OpDesc) -> Result<OpHandle> {
        self.inner.lower_op(desc)
    }

    fn run(&self, op: &OpHandle, args: &[&Value]) -> Result<Value> {
        apply(self.plan.next(), "backend")?;
        self.inner.run(op, args)
    }

    fn uploads(&self) -> usize {
        self.inner.uploads()
    }

    fn downloads(&self) -> usize {
        self.inner.downloads()
    }
}

// ---------------------------------------------------------------------------
// Session-dispatch-layer injection
// ---------------------------------------------------------------------------

/// Wrap a session/fleet dispatch function with the plan's faults: one
/// event per batch dispatch.  Hand the result to `Session::from_fn` or
/// `Fleet::deploy_fn` — injected panics are caught by the batch
/// isolation in `dispatch_batch` and poison only their own tickets.
pub fn wrap_fn<F>(
    plan: Arc<FaultPlan>,
    f: F,
) -> impl Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static
where
    F: Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static,
{
    move |x, t| {
        apply(plan.next(), "dispatch")?;
        f(x, t)
    }
}

// ---------------------------------------------------------------------------
// Wire-layer injection: the loopback fault proxy
// ---------------------------------------------------------------------------

/// Per-frame wire fault rates for [`FaultProxy`].  Applied disjointly in
/// order (`drop_conn`, `stall`, `truncate`, `corrupt`) from one uniform
/// draw per client→server frame; their sum must be ≤ 1.  All faults hit
/// a request frame **before** it is forwarded, so a faulted request
/// never reaches the server — every wire fault is retry-safe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireFaults {
    /// Discard the frame and close both sides (connection reset).
    pub drop_conn: f64,
    /// Hold the frame for `stall_ms` before forwarding (slow network;
    /// trips client read timeouts when longer than them).
    pub stall: f64,
    pub stall_ms: u64,
    /// Forward the length prefix and half the body, then close — the
    /// server's mid-frame stall budget cleans it up.
    pub truncate: f64,
    /// Flip a byte in the frame preamble before forwarding — the server
    /// sees a non-protocol frame and closes the connection.
    pub corrupt: f64,
}

impl WireFaults {
    /// A clean pass-through proxy.
    pub const NONE: WireFaults =
        WireFaults { drop_conn: 0.0, stall: 0.0, stall_ms: 0, truncate: 0.0, corrupt: 0.0 };
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WireFault {
    Drop,
    Stall(Duration),
    Truncate,
    Corrupt,
}

/// Monotonic proxy tallies.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireCounts {
    /// Client connections accepted.
    pub conns: usize,
    /// Request frames forwarded intact (stalled frames count here too).
    pub forwarded: usize,
    pub dropped: usize,
    pub stalled: usize,
    pub truncated: usize,
    pub corrupted: usize,
}

struct ProxyShared {
    upstream: SocketAddr,
    faults: WireFaults,
    rng: Mutex<Rng>,
    shutdown: AtomicBool,
    conns: AtomicUsize,
    forwarded: AtomicUsize,
    dropped: AtomicUsize,
    stalled: AtomicUsize,
    truncated: AtomicUsize,
    corrupted: AtomicUsize,
}

/// A tiny loopback TCP proxy between a [`super::net::NetClient`] and a
/// [`super::net::NetServer`] that injects frame-level faults on the
/// request path.  Frame-aware in the client→server direction (it reads
/// whole `u32 LE length + body` frames and decides per frame); the
/// response path is a raw byte pump.  Deterministic per seed: each
/// accepted connection forks its decision stream from the proxy's seeded
/// [`Rng`] by connection index.
pub struct FaultProxy {
    shared: Arc<ProxyShared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Bind an ephemeral loopback port in front of `upstream`.
    pub fn bind(upstream: SocketAddr, faults: WireFaults, seed: u64) -> Result<FaultProxy> {
        let total = faults.drop_conn + faults.stall + faults.truncate + faults.corrupt;
        anyhow::ensure!(
            (0.0..=1.0).contains(&total),
            "wire fault rates must sum into [0, 1], got {total}"
        );
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(ProxyShared {
            upstream,
            faults,
            rng: Mutex::new(Rng::new(seed)),
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            forwarded: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
            stalled: AtomicUsize::new(0),
            truncated: AtomicUsize::new(0),
            corrupted: AtomicUsize::new(0),
        });
        let sh = Arc::clone(&shared);
        let acceptor = std::thread::Builder::new()
            .name("lm-chaos-proxy".into())
            .spawn(move || accept_loop(&sh, listener))?;
        Ok(FaultProxy { shared, addr, acceptor: Some(acceptor) })
    }

    /// The proxy's listen address — point the client here.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn counts(&self) -> WireCounts {
        WireCounts {
            conns: self.shared.conns.load(Ordering::Relaxed),
            forwarded: self.shared.forwarded.load(Ordering::Relaxed),
            dropped: self.shared.dropped.load(Ordering::Relaxed),
            stalled: self.shared.stalled.load(Ordering::Relaxed),
            truncated: self.shared.truncated.load(Ordering::Relaxed),
            corrupted: self.shared.corrupted.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting and join the acceptor.  Live connection pumps
    /// notice the flag at their next poll tick and exit.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(shared: &Arc<ProxyShared>, listener: TcpListener) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((client, _)) => {
                let idx = shared.conns.fetch_add(1, Ordering::Relaxed) as u64;
                let rng = plock(&shared.rng).fork(idx);
                let sh = Arc::clone(shared);
                let _ = std::thread::Builder::new()
                    .name("lm-chaos-pump".into())
                    .spawn(move || pump_conn(&sh, client, rng));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// One proxied connection: dial upstream, pump responses raw on a side
/// thread, pump request frames with fault decisions here.
fn pump_conn(shared: &Arc<ProxyShared>, client: TcpStream, rng: Rng) {
    let Ok(server) = TcpStream::connect(shared.upstream) else {
        let _ = client.shutdown(Shutdown::Both);
        return;
    };
    let _ = client.set_nodelay(true);
    let _ = server.set_nodelay(true);
    // short read timeouts make both pumps poll the shutdown flag
    let _ = client.set_read_timeout(Some(Duration::from_millis(25)));
    let _ = server.set_read_timeout(Some(Duration::from_millis(25)));
    let (c2, s2) = match (client.try_clone(), server.try_clone()) {
        (Ok(c), Ok(s)) => (c, s),
        _ => {
            let _ = client.shutdown(Shutdown::Both);
            let _ = server.shutdown(Shutdown::Both);
            return;
        }
    };
    let sh = Arc::clone(shared);
    let resp = std::thread::Builder::new()
        .name("lm-chaos-resp".into())
        .spawn(move || pump_raw(&sh, s2, c2));
    pump_frames(shared, client, server, rng);
    if let Ok(h) = resp {
        let _ = h.join();
    }
}

/// Read a full buffer, retrying timeout ticks until shutdown; `false` on
/// EOF/error/shutdown.
fn read_full(shared: &ProxyShared, s: &mut TcpStream, buf: &mut [u8]) -> bool {
    let mut got = 0usize;
    while got < buf.len() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        match s.read(&mut buf[got..]) {
            Ok(0) => return false,
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// The faulting request pump: one frame, one decision.
fn pump_frames(shared: &ProxyShared, mut client: TcpStream, mut server: TcpStream, mut rng: Rng) {
    loop {
        let mut lb = [0u8; 4];
        if !read_full(shared, &mut client, &mut lb) {
            break;
        }
        let len = u32::from_le_bytes(lb) as usize;
        if len > super::proto::MAX_FRAME {
            // hostile length: forward the prefix verbatim and let the
            // server apply its own defense, then stop proxying
            let _ = server.write_all(&lb);
            break;
        }
        let mut body = vec![0u8; len];
        if !read_full(shared, &mut client, &mut body) {
            break;
        }
        let fault = {
            let f = &shared.faults;
            let u = rng.uniform();
            if u < f.drop_conn {
                Some(WireFault::Drop)
            } else if u < f.drop_conn + f.stall {
                Some(WireFault::Stall(Duration::from_millis(f.stall_ms)))
            } else if u < f.drop_conn + f.stall + f.truncate {
                Some(WireFault::Truncate)
            } else if u < f.drop_conn + f.stall + f.truncate + f.corrupt {
                Some(WireFault::Corrupt)
            } else {
                None
            }
        };
        match fault {
            Some(WireFault::Drop) => {
                // the frame is discarded before the server sees it:
                // from the client this is a connection reset mid-request
                shared.dropped.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Some(WireFault::Truncate) => {
                shared.truncated.fetch_add(1, Ordering::Relaxed);
                let half = len / 2;
                let _ = server.write_all(&lb);
                let _ = server.write_all(&body[..half]);
                break;
            }
            Some(WireFault::Corrupt) => {
                // flip a preamble byte: the server sees a non-protocol
                // frame, refuses it, and closes — never executes it
                shared.corrupted.fetch_add(1, Ordering::Relaxed);
                if !body.is_empty() {
                    let i = rng.below(body.len().min(4));
                    body[i] ^= 0xff;
                }
                if server.write_all(&lb).is_err() || server.write_all(&body).is_err() {
                    break;
                }
            }
            Some(WireFault::Stall(d)) => {
                shared.stalled.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(d);
                shared.forwarded.fetch_add(1, Ordering::Relaxed);
                if server.write_all(&lb).is_err() || server.write_all(&body).is_err() {
                    break;
                }
            }
            None => {
                shared.forwarded.fetch_add(1, Ordering::Relaxed);
                if server.write_all(&lb).is_err() || server.write_all(&body).is_err() {
                    break;
                }
            }
        }
        let _ = server.flush();
    }
    let _ = client.shutdown(Shutdown::Both);
    let _ = server.shutdown(Shutdown::Both);
}

/// Raw response pump (server → client), no faults.
fn pump_raw(shared: &ProxyShared, mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 8192];
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
                let _ = to.flush();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plan_is_deterministic_per_seed() {
        let spec = FaultSpec { fail: 0.2, panic: 0.1, delay: 0.1, delay_ms: 1 };
        let a = FaultPlan::random(spec, 42);
        let b = FaultPlan::random(spec, 42);
        let sa: Vec<_> = (0..200).map(|_| a.next()).collect();
        let sb: Vec<_> = (0..200).map(|_| b.next()).collect();
        assert_eq!(sa, sb);
        assert!(a.counts().injected() > 0, "rates this high must inject");
        assert_eq!(a.counts(), b.counts());
        let c = FaultPlan::random(spec, 43);
        let sc: Vec<_> = (0..200).map(|_| c.next()).collect();
        assert_ne!(sa, sc, "different seeds must differ somewhere");
    }

    #[test]
    fn nth_plan_fires_exactly_once() {
        let p = FaultPlan::nth(3, Fault::Panic);
        let seq: Vec<_> = (0..10).map(|_| p.next()).collect();
        let hits: Vec<usize> =
            seq.iter().enumerate().filter(|(_, f)| f.is_some()).map(|(i, _)| i).collect();
        assert_eq!(hits, vec![3]);
        assert_eq!(p.counts().panicked, 1);
        assert_eq!(p.counts().events, 10);
    }

    #[test]
    fn rates_partition_roughly() {
        let spec = FaultSpec { fail: 0.05, panic: 0.0, delay: 0.0, delay_ms: 0 };
        let p = FaultPlan::random(spec, 0x5eed);
        let n = 4000;
        let injected = (0..n).filter(|_| p.next().is_some()).count();
        let rate = injected as f64 / n as f64;
        assert!((0.03..0.07).contains(&rate), "5% target, got {rate}");
        assert_eq!(p.counts().failed, injected);
    }

    #[test]
    fn wrap_fn_injects_typed_failures() {
        let p = FaultPlan::nth(1, Fault::Fail);
        let f = wrap_fn(Arc::clone(&p), |x: &Tensor, _| Ok(x.clone()));
        let x = Tensor::zeros(&[1, 2]);
        assert!(f(&x, None).is_ok());
        let err = f(&x, None).expect_err("second dispatch must fail");
        assert!(err.to_string().contains("chaos"), "{err}");
        assert!(f(&x, None).is_ok());
    }

    #[test]
    fn fault_backend_delegates_transfers() {
        use crate::runtime::HostBackend;
        let inner: Arc<dyn Backend> = Arc::new(HostBackend::new());
        let fb = FaultBackend::wrap(Arc::clone(&inner), FaultPlan::none());
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let v = fb.upload(&t).unwrap();
        let back = fb.download(&v).unwrap();
        assert_eq!(back.data, t.data);
        assert_eq!(fb.uploads(), inner.uploads());
        assert_eq!(fb.downloads(), inner.downloads());
        assert_eq!(fb.name(), "chaos");
    }
}
