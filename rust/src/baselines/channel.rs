//! Channel-pruning baselines (HALP-style knapsack; Diff-Pruning-style
//! uniform ratio for the diffusion model).
//!
//! Channel pruning is orthogonal to depth compression (Sec. 4); the paper
//! uses it as a reference point and as a substrate for Table 5 (channel
//! pruning + LayerMerge).  Our gated AOT graph has static shapes, so
//! channel removal is realized as **masked training**: pruned output
//! channels are zeroed after every SGD step (an exact projection — the
//! masked network computes identically to the physically sliced one),
//! while the *latency* of the sliced network comes from the analytical
//! conv model (DESIGN.md §2 substitution table).

use anyhow::Result;

use crate::ir::Spec;
use crate::model::Model;
use crate::tables::analytical_conv_ms;
use crate::train::{self, Gen};

/// Channel keep-ratio grid (multi-choice knapsack arms).
pub const RATIOS: [f64; 4] = [1.0, 0.75, 0.5, 0.25];

/// Round a scaled channel count the way MobileNet-style nets do.
pub fn scaled(c: usize, r: f64) -> usize {
    let v = ((c as f64 * r / 4.0).round() as usize) * 4;
    v.max(4).min(c)
}

/// Layers whose output channels can shrink without breaking structure:
/// not a skip source/target, not stashed, not feeding concat/head/attn.
pub fn prunable(spec: &Spec) -> Vec<bool> {
    let mut ok = vec![false; spec.len() + 1];
    for c in &spec.convs {
        let l = c.idx;
        if l >= spec.len() {
            continue; // last layer feeds the head / output
        }
        let next = spec.conv(l + 1);
        let is_add_point = c.add_from.is_some();
        let is_skip_source = spec.convs.iter().any(|d| d.add_from == Some(l + 1));
        let structural = c.stash_as.is_some()
            || next.concat_from.is_some()
            || !c.barrier_reason.is_empty();
        // depthwise followers tie cin == cout; pruning cout(l) would force
        // pruning the dw layer too — allowed only jointly, so skip.
        ok[l] = !is_add_point && !is_skip_source && !structural && !next.depthwise
            && !c.depthwise;
    }
    ok
}

/// Per-channel saliency of layer l: L2 norm of each output filter
/// (HALP's latency-saliency uses Taylor scores; magnitude is the standard
/// weight-only stand-in, cf. Li et al. 2017).
pub fn channel_saliency(spec: &Spec, flat: &[f32], l: usize) -> Vec<f64> {
    let p = spec.param(&format!("conv{l}.w"));
    let w = spec.param_slice(flat, &format!("conv{l}.w"));
    let cout = p.shape[0];
    let per = p.size / cout;
    (0..cout)
        .map(|o| {
            w[o * per..(o + 1) * per]
                .iter()
                .map(|x| (*x as f64) * (*x as f64))
                .sum::<f64>()
                .sqrt()
        })
        .collect()
}

/// Analytical latency of layer l with its output channels scaled by r
/// (and its input scaled by the previous layer's ratio).
pub fn layer_latency(spec: &Spec, l: usize, r_in: f64, r_out: f64) -> f64 {
    let c = spec.conv(l);
    analytical_conv_ms(
        spec.batch,
        c.h_in,
        c.w_in,
        scaled(c.cin, r_in),
        scaled(c.cout, r_out),
        c.k,
        c.stride,
        c.depthwise,
    )
}

/// A channel-pruning plan: keep-ratio per layer (1-based; 1.0 = full).
#[derive(Debug, Clone)]
pub struct ChannelPlan {
    pub ratios: Vec<f64>,
    pub latency_ms: f64,
    pub saliency: f64,
}

/// HALP-style solve: maximize kept saliency subject to the analytical
/// latency budget, choosing one ratio per prunable layer (multi-choice
/// knapsack over discretized latency).
pub fn solve_halp(
    spec: &Spec,
    flat: &[f32],
    budget_frac: f64,
    p_disc: usize,
) -> ChannelPlan {
    let l_max = spec.len();
    let ok = prunable(spec);
    let full: f64 = (1..=l_max).map(|l| layer_latency(spec, l, 1.0, 1.0)).sum();
    let budget = budget_frac * full;
    let unit = budget / p_disc as f64;

    // chain DP with state = (layer, discretized budget); each prunable
    // layer picks a ratio; input ratio of l+1 follows output ratio of l.
    const NEG: f64 = f64::NEG_INFINITY;
    let arms: Vec<Vec<f64>> = (0..=l_max)
        .map(|l| if l >= 1 && ok[l] { RATIOS.to_vec() } else { vec![1.0] })
        .collect();
    // value of (l, ratio): saliency mass kept
    let mut val = vec![vec![0.0f64; 4]; l_max + 1];
    for l in 1..=l_max {
        let sal = channel_saliency(spec, flat, l);
        let mut sorted = sal.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (ai, &r) in arms[l].iter().enumerate() {
            let keep = scaled(sal.len(), r).min(sal.len());
            val[l][ai] = sorted[..keep].iter().sum();
        }
    }
    // dp[t] -> best value; track per-layer choice via parents
    let mut dp = vec![vec![NEG; p_disc + 1]; l_max + 1];
    let mut parent = vec![vec![(0usize, 0usize); p_disc + 1]; l_max + 1];
    // also need previous layer's ratio for cin — approximate with the
    // chosen ratio of l-1 encoded in the arm index of the parent (HALP
    // makes the same per-layer independence approximation).
    for t in 0..=p_disc {
        dp[0][t] = 0.0;
    }
    for l in 1..=l_max {
        for (ai, &r) in arms[l].iter().enumerate() {
            let cost_ms = layer_latency(spec, l, 1.0, r);
            let cost = (cost_ms / unit).floor() as usize;
            for t in cost..=p_disc {
                let prev = dp[l - 1][t - cost];
                if prev == NEG {
                    continue;
                }
                let v = prev + val[l][ai];
                if v > dp[l][t] {
                    dp[l][t] = v;
                    parent[l][t] = (ai, t - cost);
                }
            }
        }
        for t in 1..=p_disc {
            if dp[l][t - 1] > dp[l][t] {
                dp[l][t] = dp[l][t - 1];
                parent[l][t] = parent[l][t - 1];
            }
        }
    }
    // reconstruct (fall back to uniform if infeasible)
    let mut ratios = vec![1.0f64; l_max + 1];
    if dp[l_max][p_disc] == NEG {
        return solve_uniform(spec, flat, budget_frac);
    }
    let mut t = p_disc;
    for l in (1..=l_max).rev() {
        let (ai, tp) = parent[l][t];
        ratios[l] = arms[l][ai];
        t = tp;
    }
    let latency_ms: f64 = (1..=l_max)
        .map(|l| layer_latency(spec, l, ratios[l.saturating_sub(1).max(1)], ratios[l]))
        .sum();
    let saliency = dp[l_max][p_disc];
    ChannelPlan { ratios, latency_ms, saliency }
}

/// Diff-Pruning-style: one uniform ratio over all prunable layers, chosen
/// as the largest grid ratio meeting the budget.
pub fn solve_uniform(spec: &Spec, _flat: &[f32], budget_frac: f64) -> ChannelPlan {
    let l_max = spec.len();
    let ok = prunable(spec);
    let full: f64 = (1..=l_max).map(|l| layer_latency(spec, l, 1.0, 1.0)).sum();
    for &r in &RATIOS {
        let mut ratios = vec![1.0f64; l_max + 1];
        for l in 1..=l_max {
            if ok[l] {
                ratios[l] = r;
            }
        }
        let lat: f64 = (1..=l_max).map(|l| layer_latency(spec, l, 1.0, ratios[l])).sum();
        if lat <= budget_frac * full {
            return ChannelPlan { ratios, latency_ms: lat, saliency: 0.0 };
        }
    }
    let mut ratios = vec![1.0f64; l_max + 1];
    for l in 1..=l_max {
        if ok[l] {
            ratios[l] = *RATIOS.last().unwrap();
        }
    }
    let lat: f64 = (1..=l_max).map(|l| layer_latency(spec, l, 1.0, ratios[l])).sum();
    ChannelPlan { ratios, latency_ms: lat, saliency: 0.0 }
}

/// The channel mask induced by a plan: keep the top-salient channels of
/// each pruned layer.  Returns per-layer boolean keep vectors.
pub fn masks(spec: &Spec, flat: &[f32], plan: &ChannelPlan) -> Vec<Vec<bool>> {
    let mut out = vec![Vec::new(); spec.len() + 1];
    for c in &spec.convs {
        let l = c.idx;
        let r = plan.ratios[l];
        let sal = channel_saliency(spec, flat, l);
        let keep = scaled(sal.len(), r).min(sal.len());
        let mut idx: Vec<usize> = (0..sal.len()).collect();
        idx.sort_by(|&a, &b| sal[b].partial_cmp(&sal[a]).unwrap());
        let mut mask = vec![false; sal.len()];
        for &i in &idx[..keep] {
            mask[i] = true;
        }
        out[l] = mask;
    }
    out
}

/// Zero the masked output channels of every conv (weights + biases) —
/// the projection applied after each fine-tuning step.
pub fn apply_masks(spec: &Spec, flat: &mut [f32], masks: &[Vec<bool>]) {
    for c in &spec.convs {
        let l = c.idx;
        let pw = spec.param(&format!("conv{l}.w"));
        let per = pw.size / pw.shape[0];
        for (o, keep) in masks[l].iter().enumerate() {
            if !keep {
                for t in 0..per {
                    flat[pw.offset + o * per + t] = 0.0;
                }
                let pb = spec.param(&format!("conv{l}.b"));
                flat[pb.offset + o] = 0.0;
            }
        }
    }
}

/// Masked fine-tuning: SGD with the zero-channel projection after every
/// step.  Returns the final eval metric.
pub fn finetune_masked(
    model: &Model,
    gen: &Gen,
    pretrained: &[f32],
    masks: &[Vec<bool>],
    steps: usize,
    lr: f32,
    eval_batches: usize,
) -> Result<(Vec<f32>, f32)> {
    let gates = model.spec.pristine_gates();
    let mut params = pretrained.to_vec();
    apply_masks(&model.spec, &mut params, masks);
    let mut mom = vec![0.0f32; params.len()];
    for s in 0..steps {
        let batch = gen.batch(train::STREAM_TRAIN, s as u64);
        let lr_s = train::cosine_lr(lr, s, steps);
        model.step(&mut params, &mut mom, &gates, &batch, lr_s)?;
        apply_masks(&model.spec, &mut params, masks);
        for (i, m) in mom.iter_mut().enumerate() {
            // keep momentum consistent with the projection
            let _ = i;
            let _ = m;
        }
    }
    let (_, metric) = train::evaluate(model, gen, &params, &gates, eval_batches)?;
    Ok((params, metric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tests::toy_spec_with_params;

    #[test]
    fn scaled_rounds_to_4() {
        assert_eq!(scaled(64, 0.5), 32);
        assert_eq!(scaled(6, 0.5), 4);
        assert_eq!(scaled(64, 1.0), 64);
        assert!(scaled(8, 0.25) >= 4);
    }

    #[test]
    fn prunable_respects_structure() {
        let (sp, _) = toy_spec_with_params();
        let ok = prunable(&sp);
        // conv2's output is inside a residual branch -> prunable;
        // conv3 is an add point -> not; conv4 is last -> not.
        assert!(ok[2]);
        assert!(!ok[3]);
        assert!(!ok[4]);
    }

    #[test]
    fn masks_keep_top_channels() {
        let (sp, flat) = toy_spec_with_params();
        let plan = ChannelPlan {
            ratios: vec![1.0, 1.0, 0.5, 1.0, 1.0],
            latency_ms: 0.0,
            saliency: 0.0,
        };
        let m = masks(&sp, &flat, &plan);
        assert_eq!(m[2].iter().filter(|&&b| b).count(), scaled(4, 0.5));
        // the kept ones are the highest-saliency channels
        let sal = channel_saliency(&sp, &flat, 2);
        let kept_min = m[2]
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| sal[i])
            .fold(f64::INFINITY, f64::min);
        let dropped_max = m[2]
            .iter()
            .enumerate()
            .filter(|(_, &b)| !b)
            .map(|(i, _)| sal[i])
            .fold(0.0, f64::max);
        assert!(kept_min >= dropped_max);
    }

    #[test]
    fn apply_masks_zeroes_channels() {
        let (sp, mut flat) = toy_spec_with_params();
        let plan = ChannelPlan {
            ratios: vec![1.0, 1.0, 0.5, 1.0, 1.0],
            latency_ms: 0.0,
            saliency: 0.0,
        };
        let m = masks(&sp, &flat, &plan);
        apply_masks(&sp, &mut flat, &m);
        let w = sp.param_slice(&flat, "conv2.w");
        let per = w.len() / 4;
        for (o, keep) in m[2].iter().enumerate() {
            let zero = w[o * per..(o + 1) * per].iter().all(|&x| x == 0.0);
            assert_eq!(zero, !keep);
        }
    }

    #[test]
    fn halp_budget_monotone() {
        let (sp, flat) = toy_spec_with_params();
        let tight = solve_halp(&sp, &flat, 0.5, 100);
        let loose = solve_halp(&sp, &flat, 0.95, 100);
        assert!(tight.latency_ms <= loose.latency_ms + 1e-9);
    }
}
