//! Baselines the paper compares against (Sec. 4):
//!
//! * [`channel`]    — HALP-style latency-saliency channel-pruning knapsack
//!                    (and the Diff-Pruning-style uniform variant for the
//!                    diffusion model).
//! * [`sequential`] — the Table-6 ablation: Depth then LayerOnly,
//!                    optimized independently.
//! * Knowledge distillation lives in `train::train_distill` (Table 10/11)
//!   plus the cross-architecture KD artifact for the smaller student.

pub mod channel;
pub mod sequential;
