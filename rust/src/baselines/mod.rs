//! Baselines the paper compares against (Sec. 4):
//!
//! * [`channel`]    — HALP-style latency-saliency channel-pruning knapsack
//!                    (and the Diff-Pruning-style uniform variant for the
//!                    diffusion model).
//! * [`sequential`] — the Table-6 ablation: Depth then LayerOnly,
//!                    optimized independently.
//! * [`twostage`]   — Kim et al. 2023's two-stage DP (the predecessor
//!                    paper), solving the same surrogate problem on the
//!                    same tables for objective/solve-time comparison.
//! * Knowledge distillation lives in `train::train_distill` (Table 10/11)
//!   plus the cross-architecture KD artifact for the smaller student.

pub mod channel;
pub mod sequential;
pub mod twostage;
