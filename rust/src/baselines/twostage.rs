//! Two-stage DP baseline — "Efficient Latency-Aware CNN Depth Compression
//! via Two-Stage Dynamic Programming" (Kim et al. 2023), LayerMerge's
//! direct predecessor, adapted to our arc formulation:
//!
//! * **Stage 1** collapses every span's per-kernel-size choices into a
//!   small Pareto front over (discretized cost, importance).  Among arcs
//!   with the same source boundary and the same floored cost, only the
//!   best-importance one can appear in an optimum; and a costlier arc
//!   that gains no importance is dominated outright — the chain DP's
//!   budget-monotonicity pass makes the cheaper arc at least as good at
//!   every budget level.
//! * **Stage 2** runs the chain DP over the pruned fronts — the identical
//!   recurrence of Algorithm 1, just over far fewer arcs.
//!
//! Under the shared floor discretization (`unit = budget / P`) the
//! collapse is lossless, so the **objective equals
//! [`crate::solver::dp::solve`]'s** on the same input — pinned by the
//! property test in `tests/baselines.rs`.  The trade the predecessor
//! paper makes is solve time: stage 1 is a linear sweep, and stage 2's
//! cost scales with the front size instead of the raw kernel-option
//! count, which is where `benches/solvers.rs` compares the two.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::solver::dp::{self, DpInput, DpSolution, SpanArc};

/// Stage 1: Pareto-collapse each arc set under the input's discretization.
/// Exposed separately so tests and benches can measure the reduction.
pub fn collapse(input: &DpInput) -> Vec<Vec<SpanArc>> {
    let unit = input.budget_ms / input.p as f64;
    let mut out = Vec::with_capacity(input.arcs.len());
    for set in &input.arcs {
        if unit <= 0.0 {
            out.push(set.clone());
            continue;
        }
        // best arc per (source boundary, floored cost); ties keep the
        // truly cheaper arc so latency_est stays honest
        let mut best: BTreeMap<(usize, usize), SpanArc> = BTreeMap::new();
        for &arc in set {
            let cost = (arc.lat_ms / unit).floor() as usize;
            if cost > input.p {
                continue; // can never fit the budget
            }
            let e = best.entry((arc.i, cost)).or_insert(arc);
            if arc.imp > e.imp || (arc.imp == e.imp && arc.lat_ms < e.lat_ms) {
                *e = arc;
            }
        }
        // Pareto prune per source: the BTreeMap iterates (i, cost)
        // ascending, so within each source costs ascend — keep only
        // strictly increasing importance.
        let mut front: Vec<SpanArc> = Vec::new();
        let mut cur_src = usize::MAX;
        let mut best_imp = f64::NEG_INFINITY;
        for ((i, _cost), arc) in best {
            if i != cur_src {
                cur_src = i;
                best_imp = f64::NEG_INFINITY;
            }
            if arc.imp > best_imp {
                best_imp = arc.imp;
                front.push(arc);
            }
        }
        out.push(front);
    }
    out
}

/// Solve Problem (5) by the predecessor's two-stage scheme.  Same
/// feasibility and objective as [`dp::solve`]; `solve_ms` covers both
/// stages.
pub fn solve(input: &DpInput) -> Option<DpSolution> {
    let t0 = Instant::now();
    let arcs = collapse(input);
    let mut sol = dp::solve(&DpInput {
        l_max: input.l_max,
        budget_ms: input.budget_ms,
        p: input.p,
        arcs,
    })?;
    sol.solve_ms = t0.elapsed().as_secs_f64() * 1e3;
    Some(sol)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(arcs: Vec<Vec<SpanArc>>, budget: f64) -> DpInput {
        let l_max = arcs.len() - 1;
        DpInput { l_max, budget_ms: budget, p: 100, arcs }
    }

    #[test]
    fn collapse_drops_dominated_kernel_choices() {
        // three kernel choices for the same span: one strictly best, one
        // same-cost-worse-imp, one costlier-no-gain
        let input = inst(
            vec![
                vec![],
                vec![
                    SpanArc { i: 0, k: 3, lat_ms: 0.50, imp: 2.0 },
                    SpanArc { i: 0, k: 5, lat_ms: 0.51, imp: 1.0 }, // same bucket, worse
                    SpanArc { i: 0, k: 7, lat_ms: 0.90, imp: 1.5 }, // costlier, no gain
                ],
            ],
            1.0,
        );
        let fronts = collapse(&input);
        assert_eq!(fronts[1].len(), 1);
        assert_eq!((fronts[1][0].k, fronts[1][0].imp), (3, 2.0));
    }

    #[test]
    fn collapse_keeps_genuine_tradeoffs() {
        // paying more cost for more importance must survive
        let input = inst(
            vec![
                vec![],
                vec![
                    SpanArc { i: 0, k: 1, lat_ms: 0.10, imp: 0.5 },
                    SpanArc { i: 0, k: 3, lat_ms: 0.50, imp: 2.0 },
                    SpanArc { i: 1, k: 3, lat_ms: 0.50, imp: 1.0 }, // other source
                ],
            ],
            1.0,
        );
        let fronts = collapse(&input);
        assert_eq!(fronts[1].len(), 3, "two tradeoff arcs + the other source");
    }

    #[test]
    fn agrees_with_alg1_on_a_simple_chain() {
        let input = inst(
            vec![
                vec![],
                vec![SpanArc { i: 0, k: 3, lat_ms: 1.0, imp: 1.0 }],
                vec![
                    SpanArc { i: 1, k: 3, lat_ms: 1.0, imp: 1.0 },
                    SpanArc { i: 0, k: 5, lat_ms: 1.2, imp: 2.5 },
                ],
            ],
            1.5,
        );
        let two = solve(&input).unwrap();
        let one = dp::solve(&input).unwrap();
        assert!((two.objective - one.objective).abs() < 1e-9);
        assert_eq!(two.spans, vec![(0, 2, 5)]);
    }

    #[test]
    fn infeasible_stays_infeasible() {
        let input = inst(
            vec![vec![], vec![SpanArc { i: 0, k: 3, lat_ms: 2.0, imp: 1.0 }]],
            0.5,
        );
        assert!(solve(&input).is_none());
        assert!(dp::solve(&input).is_none());
    }
}
