//! The Table-6 ablation: sequential Depth -> LayerOnly optimization.
//!
//! The paper's baseline first runs the Depth method at ratio p1, fine-tunes,
//! then prunes whole *merged* layers of the result with LayerOnly at ratio
//! p2, splitting the fine-tuning budget equally (App. D).  Joint
//! optimization (LayerMerge) needs none of these extra hyper-parameters —
//! that is precisely the point of Table 6.

use std::collections::BTreeSet;

use anyhow::{Context, Result};

use crate::pipeline::{Compressed, Method, Pipeline};
use crate::solver::{layeronly, Solution};
use crate::train;

/// Run Depth at `p1`, fine-tune half the budget, then LayerOnly over the
/// resulting merged layers at `p2` (relative to the depth-pruned model),
/// fine-tune the other half, and deploy.
pub fn run(
    pipe: &mut Pipeline,
    p1: f64,
    p2: f64,
) -> Result<Compressed> {
    let half = pipe.cfg.finetune_steps / 2;
    // ---- phase 1: Depth ---------------------------------------------------
    let depth_sol = pipe.solve(Method::Depth, p1)?;
    let stage1 = pipe.finetune_and_deploy(Method::Depth, p1, &depth_sol, Some(half), false)?;

    // ---- phase 2: LayerOnly over merged spans -----------------------------
    // Each Depth span is one merged layer; droppable iff shape-preserving
    // (every conv in it reducible).
    let spec = pipe.model.spec.clone();
    let t = pipe.tables.as_ref().context("tables")?.clone();
    let spans = depth_sol.spans.clone();
    let n = spans.len();
    let mut lat = vec![0.0f64; n + 1];
    let mut imp = vec![0.0f64; n + 1];
    let mut forced = vec![false; n + 1];
    for (s_idx, &(i, j, k)) in spans.iter().enumerate() {
        let droppable = ((i + 1)..=j).all(|l| spec.conv(l).conv_gated);
        forced[s_idx + 1] = !droppable;
        lat[s_idx + 1] = t.entries.get(&(i, j, k)).map(|e| e.lat_ms).unwrap_or(0.1);
        if droppable {
            // keep-importance: how much dropping this merged span hurts,
            // measured on the depth-compressed fine-tuned weights.
            let mut a_set: BTreeSet<usize> = depth_sol.a.iter().copied().collect();
            a_set.remove(&j);
            let mut c_set = depth_sol.c.clone();
            for l in (i + 1)..=j {
                c_set.remove(&l);
            }
            let gates = spec.solution_gates(&a_set, &c_set, &[]);
            let perf = train::proxy_perf(
                &pipe.model, &pipe.gen, &stage1.finetuned, &gates,
                pipe.cfg.build.proxy_steps, pipe.cfg.build.proxy_lr,
                pipe.cfg.build.eval_batches,
            )?;
            imp[s_idx + 1] = ((stage1.pruned_metric - perf) as f64).exp();
        }
    }
    let depth_lat: f64 = lat.iter().sum();
    let ksol = layeronly::solve(&layeronly::KnapsackInput {
        lat_ms: lat,
        imp,
        forced,
        budget_ms: p2 * depth_lat,
        p: pipe.cfg.p_disc,
    })
    .context("sequential: phase-2 knapsack infeasible")?;

    // materialize the final solution
    let mut a: Vec<usize> = Vec::new();
    let mut c: BTreeSet<usize> = BTreeSet::new();
    let mut out_spans = Vec::new();
    for (s_idx, &(i, j, k)) in spans.iter().enumerate() {
        if ksol.kept.contains(&(s_idx + 1)) {
            out_spans.push((i, j, k));
            c.extend((i + 1)..=j);
        } else {
            out_spans.push((i, j, 1)); // dropped merged layer -> identity
        }
        if j != spec.len() {
            a.push(j);
        }
    }
    let sol = Solution {
        a,
        c,
        spans: out_spans,
        objective: ksol.objective,
        latency_est: ksol.latency_est + t.fixed_ms,
    };
    // ---- phase 2 fine-tune + deploy (continues from the stage-1 weights) --
    let mut result = pipe.finetune_and_deploy_from(
        Method::LayerOnly, p1 * p2, &sol, Some(half), false,
        Some(&stage1.finetuned),
    )?;
    result.method = format!("Depth-{:.0}% -> LayerOnly-{:.0}%", p1 * 100.0, p2 * 100.0);
    Ok(result)
}
