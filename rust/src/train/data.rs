//! Procedural synthetic datasets (DESIGN.md §2 substitution for
//! ImageNet / CIFAR-10).  Deterministic given (seed, batch index), so the
//! table builder, fine-tuning and evaluation all see the same
//! distribution and every experiment row reproduces exactly.
//!
//! * `ClassifyGen` — 10-class oriented-texture + shape task: class encodes
//!   (stripe orientation, spatial frequency, blob presence).  Solving it
//!   requires multi-scale spatial filters, so deeper/wider networks
//!   genuinely help — the property the paper's accuracy-vs-latency
//!   comparisons rely on.
//! * `DiffusionGen` — a smooth image manifold (random low-frequency blobs
//!   and gradients) for the DDPM-style denoising task.

use crate::model::Batch;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

pub const NUM_CLASSES: usize = 10;

#[derive(Debug, Clone)]
pub struct ClassifyGen {
    pub seed: u64,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub noise: f32,
}

impl ClassifyGen {
    pub fn new(seed: u64, batch: usize, h: usize, w: usize) -> Self {
        // noise level tuned so the pristine scaled-down nets land in the
        // ~85-95% accuracy band after a few hundred steps — compression
        // must have measurable headroom to hurt (cf. paper Tables 1-3).
        // LM_NOISE overrides for calibration sweeps.
        let noise = std::env::var("LM_NOISE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.5);
        ClassifyGen { seed, batch, h, w, noise }
    }

    /// Deterministic batch `idx` (train stream); use a disjoint stream tag
    /// for eval so train/eval never overlap.
    pub fn batch(&self, stream: u64, idx: u64) -> Batch {
        let mut rng = Rng::new(
            self.seed ^ stream.wrapping_mul(0x9e37_79b9) ^ idx.wrapping_mul(0x85eb_ca6b),
        );
        let (b, h, w) = (self.batch, self.h, self.w);
        let mut x = Tensor::zeros(&[b, h, w, 3]);
        let mut y = Tensor::zeros(&[b, NUM_CLASSES]);
        for n in 0..b {
            let cls = rng.below(NUM_CLASSES);
            self.render(&mut rng, &mut x, n, cls);
            y.data[n * NUM_CLASSES + cls] = 1.0;
        }
        Batch::Classify { x, y }
    }

    fn render(&self, rng: &mut Rng, x: &mut Tensor, n: usize, cls: usize) {
        let (h, w) = (self.h, self.w);
        // class -> orientation in {0..4} x frequency in {low, high};
        // neighbouring orientations are only 36 degrees apart and the two
        // frequencies are deliberately close, so the decision boundary
        // needs genuine multi-scale filtering (not a single edge detector).
        let orient = (cls % 5) as f32 * std::f32::consts::PI / 5.0
            + rng.range(-0.08, 0.08);
        let freq = (if cls < 5 { 0.45 } else { 0.72 }) * rng.range(0.92, 1.08);
        let phase = rng.range(0.0, std::f32::consts::TAU);
        let (sa, ca) = orient.sin_cos();
        // a faint blob adds a second cue correlated with class parity
        let blob = cls % 2 == 0;
        let (bx, by) = (rng.range(6.0, w as f32 - 6.0), rng.range(6.0, h as f32 - 6.0));
        let br = rng.range(2.5, 4.0);
        // distractor texture: an uncorrelated second grating
        let d_or = rng.range(0.0, std::f32::consts::PI);
        let (dsa, dca) = d_or.sin_cos();
        let d_freq = rng.range(0.3, 0.9);
        let d_phase = rng.range(0.0, std::f32::consts::TAU);
        for i in 0..h {
            for j in 0..w {
                let (fi, fj) = (i as f32, j as f32);
                let t = (fi * ca + fj * sa) * freq + phase;
                let stripe = t.sin() * 0.8;
                let distract = ((fi * dca + fj * dsa) * d_freq + d_phase).sin() * 0.45;
                let mut v = [stripe + distract, stripe * 0.6 - distract * 0.3,
                             -stripe * 0.4 + distract * 0.2];
                if blob {
                    let d2 = (fi - by).powi(2) + (fj - bx).powi(2);
                    let g = (-d2 / (2.0 * br * br)).exp();
                    v[0] += 0.9 * g;
                    v[2] += 0.7 * g;
                }
                for (c, val) in v.iter().enumerate() {
                    let noise = rng.normal() * self.noise;
                    x.set4(n, i, j, c, (val + noise).clamp(-2.5, 2.5));
                }
            }
        }
    }
}

/// Diffusion-task data: clean images x0 plus the noise/timestep tensors
/// the AOT train/eval graphs expect.  The cosine abar schedule lives here
/// (mirrored by `DiffusionGen::abar`).
#[derive(Debug, Clone)]
pub struct DiffusionGen {
    pub seed: u64,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub t_max: usize,
}

impl DiffusionGen {
    pub fn new(seed: u64, batch: usize, h: usize, w: usize) -> Self {
        DiffusionGen { seed, batch, h, w, t_max: 1000 }
    }

    /// Cosine cumulative alpha-bar schedule (Nichol & Dhariwal).
    pub fn abar(&self, t: f32) -> f32 {
        let s = 0.008f32;
        let f = |u: f32| (((u / self.t_max as f32 + s) / (1.0 + s))
            * std::f32::consts::FRAC_PI_2)
            .cos()
            .powi(2);
        (f(t) / f(0.0)).clamp(1e-4, 0.9999)
    }

    pub fn clean(&self, rng: &mut Rng) -> Vec<f32> {
        let (h, w) = (self.h, self.w);
        let mut img = vec![0.0f32; h * w * 3];
        // smooth background gradient
        let (gx, gy) = (rng.range(-0.5, 0.5), rng.range(-0.5, 0.5));
        let base = [rng.range(-0.4, 0.4), rng.range(-0.4, 0.4), rng.range(-0.4, 0.4)];
        let nblobs = 1 + rng.below(3);
        let blobs: Vec<(f32, f32, f32, [f32; 3])> = (0..nblobs)
            .map(|_| {
                (
                    rng.range(2.0, w as f32 - 2.0),
                    rng.range(2.0, h as f32 - 2.0),
                    rng.range(1.5, 4.5),
                    [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)],
                )
            })
            .collect();
        for i in 0..h {
            for j in 0..w {
                for c in 0..3 {
                    let mut v = base[c]
                        + gx * (j as f32 / w as f32 - 0.5)
                        + gy * (i as f32 / h as f32 - 0.5);
                    for (bx, by, r, col) in &blobs {
                        let d2 = (i as f32 - by).powi(2) + (j as f32 - bx).powi(2);
                        v += col[c] * (-d2 / (2.0 * r * r)).exp();
                    }
                    img[(i * w + j) * 3 + c] = v.clamp(-1.0, 1.0);
                }
            }
        }
        img
    }

    pub fn batch(&self, stream: u64, idx: u64) -> Batch {
        let mut rng = Rng::new(
            self.seed ^ stream.wrapping_mul(0xc2b2_ae35) ^ idx.wrapping_mul(0x2545_f491),
        );
        let (b, h, w) = (self.batch, self.h, self.w);
        let mut x0 = Tensor::zeros(&[b, h, w, 3]);
        let mut eps = Tensor::zeros(&[b, h, w, 3]);
        let mut t = Tensor::zeros(&[b]);
        let mut ab = Tensor::zeros(&[b]);
        for n in 0..b {
            let img = self.clean(&mut rng);
            let off = n * h * w * 3;
            x0.data[off..off + img.len()].copy_from_slice(&img);
            for v in &mut eps.data[off..off + img.len()] {
                *v = rng.normal();
            }
            let tt = rng.range(1.0, self.t_max as f32 - 1.0);
            t.data[n] = tt;
            ab.data[n] = self.abar(tt);
        }
        Batch::Diffusion { x0, eps, t, abar: ab }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_batches_deterministic() {
        let g = ClassifyGen::new(7, 4, 16, 16);
        let a = g.batch(0, 3);
        let b = g.batch(0, 3);
        match (a, b) {
            (Batch::Classify { x: xa, y: ya }, Batch::Classify { x: xb, y: yb }) => {
                assert_eq!(xa.data, xb.data);
                assert_eq!(ya.data, yb.data);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn classify_streams_differ() {
        let g = ClassifyGen::new(7, 4, 16, 16);
        let (a, b) = (g.batch(0, 1), g.batch(1, 1));
        match (a, b) {
            (Batch::Classify { x: xa, .. }, Batch::Classify { x: xb, .. }) => {
                assert!(xa.max_abs_diff(&xb) > 1e-3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn labels_one_hot() {
        let g = ClassifyGen::new(1, 8, 16, 16);
        if let Batch::Classify { y, .. } = g.batch(0, 0) {
            for n in 0..8 {
                let row = &y.data[n * NUM_CLASSES..(n + 1) * NUM_CLASSES];
                assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
                assert_eq!(row.iter().sum::<f32>(), 1.0);
            }
        }
    }

    #[test]
    fn abar_monotone_decreasing() {
        let g = DiffusionGen::new(1, 2, 8, 8);
        let mut prev = g.abar(0.0);
        for t in (50..1000).step_by(50) {
            let a = g.abar(t as f32);
            assert!(a <= prev + 1e-6, "abar not decreasing at t={t}");
            assert!((1e-5..=1.0).contains(&a));
            prev = a;
        }
    }

    #[test]
    fn diffusion_batch_shapes() {
        let g = DiffusionGen::new(3, 2, 8, 8);
        if let Batch::Diffusion { x0, eps, t, abar } = g.batch(0, 0) {
            assert_eq!(x0.dims, vec![2, 8, 8, 3]);
            assert_eq!(eps.dims, x0.dims);
            assert_eq!(t.dims, vec![2]);
            assert_eq!(abar.dims, vec![2]);
            assert!(x0.data.iter().all(|v| (-1.0..=1.0).contains(v)));
        } else {
            unreachable!()
        }
    }
}
