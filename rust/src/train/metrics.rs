//! Evaluation metrics beyond loss/accuracy.
//!
//! FDD — Fréchet Descriptor Distance: the FID substitution of DESIGN.md §2.
//! FID is the Fréchet distance between Gaussians fitted to Inception-V3
//! features of real vs generated images; we keep the metric and swap the
//! embedder for our pretrained `resnetish` classifier's penultimate
//! features.  We use the diagonal-covariance form
//!
//!   FDD = ||mu_r - mu_g||^2 + sum_d (sqrt(var_r,d) - sqrt(var_g,d))^2
//!
//! (the full-covariance matrix-sqrt term degenerates to this for diagonal
//! fits; with feature dims >> sample counts here, diagonal estimation is
//! the statistically sane choice).

use crate::util::tensor::Tensor;

/// Per-dimension mean and variance over a set of feature rows [N, D].
pub fn feature_stats(feats: &Tensor) -> (Vec<f64>, Vec<f64>) {
    let (n, d) = (feats.dims[0], feats.dims[1]);
    assert!(n > 1, "need > 1 sample for variance");
    let mut mean = vec![0.0f64; d];
    for r in 0..n {
        for c in 0..d {
            mean[c] += feats.data[r * d + c] as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; d];
    for r in 0..n {
        for c in 0..d {
            let diff = feats.data[r * d + c] as f64 - mean[c];
            var[c] += diff * diff;
        }
    }
    for v in &mut var {
        *v /= (n - 1) as f64;
    }
    (mean, var)
}

/// Fréchet distance between diagonal Gaussians.
pub fn frechet_diag(mu1: &[f64], var1: &[f64], mu2: &[f64], var2: &[f64]) -> f64 {
    mu1.iter()
        .zip(mu2)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        + var1
            .iter()
            .zip(var2)
            .map(|(a, b)| (a.max(0.0).sqrt() - b.max(0.0).sqrt()).powi(2))
            .sum::<f64>()
}

/// FDD between two feature sets.
pub fn fdd(real: &Tensor, gen: &Tensor) -> f64 {
    let (m1, v1) = feature_stats(real);
    let (m2, v2) = feature_stats(gen);
    frechet_diag(&m1, &v1, &m2, &v2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(rng: &mut Rng, n: usize, d: usize, mu: f32, sd: f32) -> Tensor {
        Tensor::new(
            vec![n, d],
            (0..n * d).map(|_| mu + sd * rng.normal()).collect(),
        )
    }

    #[test]
    fn identical_distributions_near_zero() {
        let mut r = Rng::new(1);
        let a = sample(&mut r, 400, 8, 0.0, 1.0);
        let b = sample(&mut r, 400, 8, 0.0, 1.0);
        assert!(fdd(&a, &b) < 0.1, "fdd = {}", fdd(&a, &b));
    }

    #[test]
    fn mean_shift_detected() {
        let mut r = Rng::new(2);
        let a = sample(&mut r, 400, 8, 0.0, 1.0);
        let b = sample(&mut r, 400, 8, 2.0, 1.0);
        let d = fdd(&a, &b);
        assert!(d > 8.0 * 3.0, "fdd = {d}"); // ~ 8 dims * (2)^2 = 32
    }

    #[test]
    fn scale_shift_detected() {
        let mut r = Rng::new(3);
        let a = sample(&mut r, 500, 4, 0.0, 1.0);
        let b = sample(&mut r, 500, 4, 0.0, 3.0);
        assert!(fdd(&a, &b) > 4.0 * 2.0, "fdd = {}", fdd(&a, &b));
    }

    #[test]
    fn symmetric() {
        let mut r = Rng::new(4);
        let a = sample(&mut r, 100, 4, 0.5, 1.0);
        let b = sample(&mut r, 100, 4, -0.5, 2.0);
        assert!((fdd(&a, &b) - fdd(&b, &a)).abs() < 1e-9);
    }
}
