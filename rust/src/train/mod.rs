//! Training substrate: synthetic data, pretrain/fine-tune drivers with the
//! paper's cosine schedule, and evaluation metrics (accuracy, diffusion
//! loss, FDD).

pub mod data;
pub mod metrics;

use anyhow::Result;

use crate::ir::{Gates, Task};
use crate::model::{Batch, Model};
use crate::train::data::{ClassifyGen, DiffusionGen};

/// Train/eval stream tags (disjoint data).
pub const STREAM_TRAIN: u64 = 0;
pub const STREAM_EVAL: u64 = 1;
/// The importance builder's fine-tuning subset (App. C uses a small random
/// subset of train; a distinct stream models that).
pub const STREAM_PROXY: u64 = 2;

/// Data source matching a model's task.
pub enum Gen {
    Classify(ClassifyGen),
    Diffusion(DiffusionGen),
}

impl Gen {
    pub fn for_model(m: &Model, seed: u64) -> Gen {
        match m.spec.task {
            Task::Classify => Gen::Classify(ClassifyGen::new(
                seed, m.spec.batch, m.spec.h, m.spec.w,
            )),
            Task::Diffusion => Gen::Diffusion(DiffusionGen::new(
                seed, m.spec.batch, m.spec.h, m.spec.w,
            )),
        }
    }

    pub fn batch(&self, stream: u64, idx: u64) -> Batch {
        match self {
            Gen::Classify(g) => g.batch(stream, idx),
            Gen::Diffusion(g) => g.batch(stream, idx),
        }
    }
}

/// Cosine learning-rate decay with a short linear warmup — the App. E
/// fine-tuning schedule plus the warmup that keeps the norm-free nets out
/// of the dead-ReLU basin at high LR.
pub fn cosine_lr(base: f32, step: usize, total: usize) -> f32 {
    let total = total.max(1);
    let warm = (total / 20).max(3).min(total);
    let scale = ((step + 1) as f32 / warm as f32).min(1.0);
    let p = step as f32 / total as f32;
    0.5 * base * scale * (1.0 + (std::f32::consts::PI * p).cos())
}

#[derive(Debug, Clone)]
pub struct TrainLog {
    pub steps: usize,
    pub final_loss: f32,
    pub final_metric: f32,
    /// (step, eval_loss, eval_metric) checkpoints — Fig. 3/4 recovery curves.
    pub curve: Vec<(usize, f32, f32)>,
}

/// Run `steps` SGD steps with cosine LR; evaluates every `eval_every`
/// steps on the eval stream (0 disables the curve).
pub fn train(
    model: &Model,
    gen: &Gen,
    params: &mut Vec<f32>,
    gates: &Gates,
    steps: usize,
    base_lr: f32,
    eval_every: usize,
) -> Result<TrainLog> {
    let mut mom = vec![0.0f32; params.len()];
    let mut log = TrainLog { steps, final_loss: 0.0, final_metric: 0.0, curve: vec![] };
    for s in 0..steps {
        let batch = gen.batch(STREAM_TRAIN, s as u64);
        let lr = cosine_lr(base_lr, s, steps);
        let (loss, metric) = model.step(params, &mut mom, gates, &batch, lr)?;
        log.final_loss = loss;
        log.final_metric = metric;
        if eval_every > 0 && (s + 1) % eval_every == 0 {
            let (el, em) = evaluate(model, gen, params, gates, 4)?;
            log.curve.push((s + 1, el, em));
        }
    }
    Ok(log)
}

/// KD fine-tuning (Table 11): same loop through the distill_step graph.
pub fn train_distill(
    model: &Model,
    gen: &Gen,
    teacher: &[f32],
    params: &mut Vec<f32>,
    gates: &Gates,
    steps: usize,
    base_lr: f32,
) -> Result<TrainLog> {
    let mut mom = vec![0.0f32; params.len()];
    let mut log = TrainLog { steps, final_loss: 0.0, final_metric: 0.0, curve: vec![] };
    for s in 0..steps {
        let batch = gen.batch(STREAM_TRAIN, s as u64);
        let lr = cosine_lr(base_lr, s, steps);
        let (loss, metric) =
            model.distill(teacher, params, &mut mom, gates, &batch, lr)?;
        log.final_loss = loss;
        log.final_metric = metric;
    }
    Ok(log)
}

/// Mean (loss, metric) over `n` eval-stream batches.
pub fn evaluate(
    model: &Model,
    gen: &Gen,
    params: &[f32],
    gates: &Gates,
    n: usize,
) -> Result<(f32, f32)> {
    let (mut l, mut m) = (0.0, 0.0);
    for i in 0..n {
        let batch = gen.batch(STREAM_EVAL, i as u64);
        let (li, mi) = model.eval(params, gates, &batch)?;
        l += li;
        m += mi;
    }
    Ok((l / n as f32, m / n as f32))
}

/// Few-step fine-tune + evaluate for the importance tables (Eq. 4's inner
/// max, estimated per App. C "fine-tuning for a few steps on a subset").
/// Returns the post-fine-tune metric (Perf).
pub fn proxy_perf(
    model: &Model,
    gen: &Gen,
    pretrained: &[f32],
    gates: &Gates,
    steps: usize,
    lr: f32,
    eval_batches: usize,
) -> Result<f32> {
    let mut params = pretrained.to_vec();
    let mut mom = vec![0.0f32; params.len()];
    for s in 0..steps {
        let batch = gen.batch(STREAM_PROXY, s as u64);
        model.step(&mut params, &mut mom, gates, &batch, lr)?;
    }
    let (_, metric) = evaluate(model, gen, &params, gates, eval_batches)?;
    Ok(metric)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_endpoints() {
        // warmup ramps linearly over the first ~5% of steps
        assert!(cosine_lr(0.1, 0, 100) < 0.05);
        assert!((cosine_lr(0.1, 4, 100) - 0.1 * 0.5 * (1.0 + (0.04 * std::f32::consts::PI).cos())).abs() < 1e-5);
        assert!(cosine_lr(0.1, 100, 100) < 1e-6);
        assert!(cosine_lr(0.1, 50, 100) > 0.04);
        assert!(cosine_lr(0.1, 50, 100) < 0.06);
    }
}
