//! Algorithm 1 — the exact DP for the surrogate Problem (5).
//!
//! State: M[l][t] = max importance sum covering layers 1..l within
//! discretized latency budget t (Eq. 6/7).  Latencies are rounded *down*
//! to multiples of T0/P, matching the paper's protocol (App. C: multiply
//! by 10 and floor, i.e. P = 10·T0).  Theorem 3.1 (optimality) is pinned
//! by `matches_bruteforce` below.

use std::time::Instant;

/// One feasible merged layer: span (i, j] realized at kernel size k.
#[derive(Debug, Clone, Copy)]
pub struct SpanArc {
    pub i: usize,
    pub k: usize,
    pub lat_ms: f64,
    pub imp: f64,
}

#[derive(Debug, Clone)]
pub struct DpInput {
    pub l_max: usize,
    /// Latency budget for the DP (T0 minus the model's fixed costs).
    pub budget_ms: f64,
    /// Discretization level P.
    pub p: usize,
    /// arcs[j] (1-based j, index 0 unused) = feasible spans ending at j.
    pub arcs: Vec<Vec<SpanArc>>,
}

#[derive(Debug, Clone)]
pub struct DpSolution {
    /// Interior boundaries (the kept-activation set A*, ascending).
    pub a: Vec<usize>,
    /// Chosen spans (i, j, k).
    pub spans: Vec<(usize, usize, usize)>,
    pub objective: f64,
    pub latency_est: f64,
    pub solve_ms: f64,
}

/// Solve Problem (5). Returns None when no full cover fits the budget.
pub fn solve(input: &DpInput) -> Option<DpSolution> {
    let t0 = Instant::now();
    let (l_max, p) = (input.l_max, input.p);
    assert!(p > 0 && input.arcs.len() == l_max + 1);
    let unit = input.budget_ms / p as f64;
    if unit <= 0.0 {
        return None;
    }
    let disc = |ms: f64| -> usize { (ms / unit).floor() as usize };

    const NEG: f64 = f64::NEG_INFINITY;
    // M[l][t]; parent[l][t] = (index into arcs[l], t') for reconstruction.
    // The arc *index* (not its (i, k) signature) is stored: feasible sets
    // can hold duplicate (i, k) arcs for a span — e.g. re-measured latency
    // entries — and a signature lookup would resolve to whichever
    // duplicate comes first, misreporting latency_est.
    let mut m = vec![vec![NEG; p + 1]; l_max + 1];
    let mut parent = vec![vec![(usize::MAX, 0usize); p + 1]; l_max + 1];
    for t in 0..=p {
        m[0][t] = 0.0;
    }
    for j in 1..=l_max {
        for (ai, arc) in input.arcs[j].iter().enumerate() {
            let cost = disc(arc.lat_ms);
            for t in cost..=p {
                let prev = m[arc.i][t - cost];
                if prev == NEG {
                    continue;
                }
                let v = prev + arc.imp;
                if v > m[j][t] {
                    m[j][t] = v;
                    parent[j][t] = (ai, t - cost);
                }
            }
        }
        // budget monotonicity: a larger t is always at least as good
        for t in 1..=p {
            if m[j][t - 1] > m[j][t] {
                m[j][t] = m[j][t - 1];
                parent[j][t] = parent[j][t - 1];
            }
        }
    }
    if m[l_max][p] == NEG {
        return None;
    }

    // Reconstruct the chain of spans from (L, P).
    let mut spans = Vec::new();
    let mut latency = 0.0;
    let (mut j, mut t) = (l_max, p);
    while j > 0 {
        let (ai, tp) = parent[j][t];
        assert_ne!(ai, usize::MAX, "broken parent chain at ({j},{t})");
        let arc = input.arcs[j][ai];
        latency += arc.lat_ms;
        spans.push((arc.i, j, arc.k));
        j = arc.i;
        t = tp;
    }
    spans.reverse();
    let a: Vec<usize> = spans[..spans.len().saturating_sub(1)]
        .iter()
        .map(|&(_, j, _)| j)
        .collect();
    Some(DpSolution {
        a,
        objective: m[l_max][p],
        spans,
        latency_est: latency,
        solve_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_res;
    use crate::util::rng::Rng;

    /// Random chain instances solved both by the DP and by brute-force
    /// enumeration over all boundary sets and kernel choices — the
    /// executable form of Theorem 3.1.
    #[test]
    fn matches_bruteforce() {
        check_res("alg1 == bruteforce", 120, gen_instance, |inst| {
            let got = solve(inst);
            let want = brute(inst);
            match (got, want) {
                (None, None) => Ok(()),
                (Some(g), Some(wobj)) => {
                    if (g.objective - wobj).abs() > 1e-9 {
                        Err(format!("obj {} vs brute {}", g.objective, wobj))
                    } else if g.latency_est >= inst.budget_ms + 1e-9 + slack(inst) {
                        Err(format!("latency {} over budget {}", g.latency_est,
                            inst.budget_ms))
                    } else {
                        Ok(())
                    }
                }
                (g, w) => Err(format!("feasibility mismatch: {:?} vs {:?}",
                    g.map(|s| s.objective), w)),
            }
        });
    }

    /// Discretization rounds each arc down by < unit, so the true latency may
    /// exceed the budget by at most (#spans)·unit — the standard DP-
    /// discretization slack the paper accepts via P large.
    fn slack(inst: &DpInput) -> f64 {
        inst.l_max as f64 * inst.budget_ms / inst.p as f64
    }

    fn gen_instance(r: &mut Rng) -> DpInput {
        let l = 2 + r.below(4);
        let p = 40 + r.below(60);
        let mut arcs = vec![Vec::new(); l + 1];
        for j in 1..=l {
            for i in 0..j {
                // random subset of kernel options per span
                for k in [1usize, 3, 5] {
                    if r.uniform() < 0.7 {
                        arcs[j].push(SpanArc {
                            i,
                            k,
                            lat_ms: r.range(0.1, 2.0) as f64,
                            imp: r.uniform() * 3.0,
                        });
                    }
                }
            }
        }
        DpInput { l_max: l, budget_ms: r.range(0.5, 5.0) as f64, p, arcs }
    }

    fn brute(inst: &DpInput) -> Option<f64> {
        // enumerate all chains 0 = b0 < b1 < ... < bm = L and per-span arcs
        let unit = inst.budget_ms / inst.p as f64;
        fn rec(inst: &DpInput, unit: f64, at: usize, used: usize, obj: f64,
               best: &mut Option<f64>) {
            if at == inst.l_max {
                if best.map_or(true, |b| obj > b) {
                    *best = Some(obj);
                }
                return;
            }
            for j in (at + 1)..=inst.l_max {
                for arc in &inst.arcs[j] {
                    if arc.i != at {
                        continue;
                    }
                    let cost = (arc.lat_ms / unit).floor() as usize;
                    if used + cost <= inst.p {
                        rec(inst, unit, j, used + cost, obj + arc.imp, best);
                    }
                }
            }
        }
        let mut best = None;
        rec(inst, unit, 0, 0, 0.0, &mut best);
        best
    }

    #[test]
    fn simple_chain() {
        // two layers; merging both (span (0,2]) is cheap and valuable
        let arcs = vec![
            vec![],
            vec![SpanArc { i: 0, k: 3, lat_ms: 1.0, imp: 1.0 }],
            vec![
                SpanArc { i: 1, k: 3, lat_ms: 1.0, imp: 1.0 },
                SpanArc { i: 0, k: 5, lat_ms: 1.2, imp: 2.5 },
            ],
        ];
        let inst = DpInput { l_max: 2, budget_ms: 1.5, p: 100, arcs };
        let sol = solve(&inst).unwrap();
        assert_eq!(sol.spans, vec![(0, 2, 5)]);
        assert!(sol.a.is_empty());

        // tighter budget forbids nothing (1.2 < 1.5) but a 0.9 budget
        // forces... nothing fits (needs >= 1.0+1.0 or 1.2) -> None
        let inst2 = DpInput { l_max: 2, budget_ms: 0.9, p: 100, ..inst };
        assert!(solve(&inst2).is_none());
    }

    #[test]
    fn prefers_higher_importance_within_budget() {
        let arcs = vec![
            vec![],
            vec![
                SpanArc { i: 0, k: 1, lat_ms: 0.2, imp: 0.5 },
                SpanArc { i: 0, k: 3, lat_ms: 0.8, imp: 2.0 },
            ],
        ];
        let inst = DpInput { l_max: 1, budget_ms: 1.0, p: 50, arcs };
        let sol = solve(&inst).unwrap();
        assert_eq!(sol.spans[0].2, 3);
    }

    /// Duplicate (i, k) arcs for the same span (re-measured latency
    /// entries): the reconstruction must report the latency of the arc
    /// the DP actually chose, not of the first (i, k) match.  The
    /// signature-based `find(|a| a.i == i && a.k == k)` lookup this test
    /// guards against resolved to the 0.9 ms decoy below.
    #[test]
    fn duplicate_arcs_resolve_to_the_chosen_index() {
        let arcs = vec![
            vec![],
            vec![
                SpanArc { i: 0, k: 3, lat_ms: 0.9, imp: 0.5 }, // decoy: same (i, k)
                SpanArc { i: 0, k: 3, lat_ms: 0.2, imp: 2.0 }, // the DP's pick
            ],
        ];
        let inst = DpInput { l_max: 1, budget_ms: 1.0, p: 100, arcs };
        let sol = solve(&inst).unwrap();
        assert_eq!(sol.spans, vec![(0, 1, 3)]);
        assert!((sol.objective - 2.0).abs() < 1e-9, "objective {}", sol.objective);
        assert!(
            (sol.latency_est - 0.2).abs() < 1e-9,
            "latency_est {} reports the decoy arc's latency",
            sol.latency_est
        );

        // the other order too: chosen arc first, decoy second
        let arcs = vec![
            vec![],
            vec![
                SpanArc { i: 0, k: 3, lat_ms: 0.2, imp: 2.0 },
                SpanArc { i: 0, k: 3, lat_ms: 0.9, imp: 0.5 },
            ],
        ];
        let inst = DpInput { l_max: 1, budget_ms: 1.0, p: 100, arcs };
        let sol = solve(&inst).unwrap();
        assert!((sol.latency_est - 0.2).abs() < 1e-9, "latency_est {}", sol.latency_est);
    }
}
