//! The *Depth* baseline (Kim et al. 2023): depth compression that only
//! removes activation layers and keeps every convolution (C = [L]).
//!
//! In our formulation this is Algorithm 1 restricted to arcs whose kernel
//! size is the *full* merged kernel k_full(i, j) = 1 + Σ_{l∈(i,j]} inc(l)
//! — precisely the restriction whose kernel-size growth Fig. 1 of the
//! paper diagnoses.  Spans whose k_full exceeds K_MAX are unavailable
//! (they are never latency-optimal; DESIGN.md §2).

use crate::ir::Spec;
use crate::solver::dp::{self, DpInput, SpanArc};

/// Full merged kernel size of span (i, j] when every conv is kept.
pub fn k_full(spec: &Spec, i: usize, j: usize) -> usize {
    1 + ((i + 1)..=j).map(|l| spec.k_increment(i, l)).sum::<usize>()
}

/// Restrict a LayerMerge arc set to the Depth baseline's search space.
pub fn restrict_arcs(spec: &Spec, arcs: &[Vec<SpanArc>]) -> Vec<Vec<SpanArc>> {
    let mut out = vec![Vec::new(); arcs.len()];
    for (j, list) in arcs.iter().enumerate() {
        for arc in list {
            if j >= 1 && arc.k == k_full(spec, arc.i, j) {
                out[j].push(*arc);
            }
        }
    }
    out
}

/// Solve the Depth baseline over the shared tables.
pub fn solve(
    spec: &Spec,
    l_max: usize,
    budget_ms: f64,
    p: usize,
    arcs: &[Vec<SpanArc>],
) -> Option<dp::DpSolution> {
    let restricted = restrict_arcs(spec, arcs);
    dp::solve(&DpInput { l_max, budget_ms, p, arcs: restricted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tests::toy_spec;

    #[test]
    fn k_full_matches_eq1() {
        let sp = toy_spec();
        // layers 2..=4 have kernels 3,3,1 -> k_full(1,4) = 1 + 2 + 2 + 0
        assert_eq!(k_full(&sp, 1, 4), 5);
        assert_eq!(k_full(&sp, 0, 4), 7); // stem k=3 adds 2
        assert_eq!(k_full(&sp, 3, 4), 1); // only the 1x1
    }

    #[test]
    fn restriction_drops_pruned_kernels() {
        let sp = toy_spec();
        let arcs = vec![
            vec![],
            vec![SpanArc { i: 0, k: 3, lat_ms: 1.0, imp: 1.0 }],
            vec![],
            vec![],
            vec![
                SpanArc { i: 1, k: 3, lat_ms: 1.0, imp: 9.0 }, // pruned-conv arc
                SpanArc { i: 1, k: 5, lat_ms: 2.0, imp: 1.0 }, // full-kernel arc
            ],
        ];
        let r = restrict_arcs(&sp, &arcs);
        assert_eq!(r[4].len(), 1);
        assert_eq!(r[4][0].k, 5);
        assert_eq!(r[1].len(), 1); // single-layer span: k == k_full trivially
    }
}
