//! The *Depth* baseline (Kim et al. 2023): depth compression that only
//! removes activation layers and keeps every convolution (C = [L]).
//!
//! In our formulation this is Algorithm 1 restricted to arcs whose kernel
//! size is the *full* merged kernel k_full(i, j) = 1 + Σ_{l∈(i,j]} inc(l)
//! — precisely the restriction whose kernel-size growth Fig. 1 of the
//! paper diagnoses.  Spans whose k_full exceeds K_MAX are unavailable
//! (they are never latency-optimal; DESIGN.md §2).

use std::collections::BTreeSet;

use crate::ir::Spec;
use crate::solver::dp::{self, DpInput, SpanArc};

/// Full merged kernel size of span (i, j] when every conv is kept.
pub fn k_full(spec: &Spec, i: usize, j: usize) -> usize {
    1 + ((i + 1)..=j).map(|l| spec.k_increment(i, l)).sum::<usize>()
}

/// Greedily cover every segment with the *largest* valid spans whose full
/// kernel stays achievable (k_full ∈ K_ij, i.e. within K_MAX) — the Depth
/// baseline's extreme point, built from spec combinatorics alone (no
/// latency/importance tables).  Used by the host-backend `serve` /
/// `profile` paths and the exec equivalence tests as a table-free
/// depth-compressed solution.  Returns `(a, c, spans)` for
/// [`crate::exec::Plan::from_solution`].
pub fn greedy_full_solution(
    spec: &Spec,
) -> (Vec<usize>, BTreeSet<usize>, Vec<(usize, usize, usize)>) {
    let mut a: Vec<usize> = Vec::new();
    let mut spans: Vec<(usize, usize, usize)> = Vec::new();
    for (s, e) in spec.segments() {
        let mut i = s - 1;
        while i < e {
            let mut j_pick = i + 1;
            for j in ((i + 1)..=e).rev() {
                if spec.valid_span(i, j) {
                    let kf = k_full(spec, i, j);
                    if spec.kernel_options(i, j).contains(&kf) {
                        j_pick = j;
                        break;
                    }
                }
            }
            spans.push((i, j_pick, k_full(spec, i, j_pick)));
            if j_pick != spec.len() {
                a.push(j_pick);
            }
            i = j_pick;
        }
    }
    let c: BTreeSet<usize> = (1..=spec.len()).collect();
    (a, c, spans)
}

/// Restrict a LayerMerge arc set to the Depth baseline's search space.
pub fn restrict_arcs(spec: &Spec, arcs: &[Vec<SpanArc>]) -> Vec<Vec<SpanArc>> {
    let mut out = vec![Vec::new(); arcs.len()];
    for (j, list) in arcs.iter().enumerate() {
        for arc in list {
            if j >= 1 && arc.k == k_full(spec, arc.i, j) {
                out[j].push(*arc);
            }
        }
    }
    out
}

/// Solve the Depth baseline over the shared tables.
pub fn solve(
    spec: &Spec,
    l_max: usize,
    budget_ms: f64,
    p: usize,
    arcs: &[Vec<SpanArc>],
) -> Option<dp::DpSolution> {
    let restricted = restrict_arcs(spec, arcs);
    dp::solve(&DpInput { l_max, budget_ms, p, arcs: restricted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tests::toy_spec;

    #[test]
    fn k_full_matches_eq1() {
        let sp = toy_spec();
        // layers 2..=4 have kernels 3,3,1 -> k_full(1,4) = 1 + 2 + 2 + 0
        assert_eq!(k_full(&sp, 1, 4), 5);
        assert_eq!(k_full(&sp, 0, 4), 7); // stem k=3 adds 2
        assert_eq!(k_full(&sp, 3, 4), 1); // only the 1x1
    }

    #[test]
    fn greedy_cover_is_valid_and_contiguous() {
        for (spec, _) in [
            crate::ir::synth::by_name("hostnet").unwrap(),
            crate::ir::synth::by_name("hostchain").unwrap(),
        ] {
            let (a, c, spans) = greedy_full_solution(&spec);
            assert_eq!(c.len(), spec.len(), "Depth keeps every conv");
            // spans tile 0..L contiguously and are all valid
            let mut prev = 0usize;
            for &(i, j, k) in &spans {
                assert_eq!(i, prev, "gap in span cover");
                assert!(spec.valid_span(i, j), "invalid span ({i},{j}]");
                assert_eq!(k, k_full(&spec, i, j));
                assert!(spec.kernel_options(i, j).contains(&k));
                prev = j;
            }
            assert_eq!(prev, spec.len());
            // kept boundaries = interior span ends
            let ends: Vec<usize> =
                spans.iter().map(|&(_, j, _)| j).filter(|&j| j != spec.len()).collect();
            assert_eq!(a, ends);
            assert!(
                spans.iter().any(|&(i, j, _)| j - i > 1),
                "expected at least one real merge in {spans:?}"
            );
        }
    }

    #[test]
    fn restriction_drops_pruned_kernels() {
        let sp = toy_spec();
        let arcs = vec![
            vec![],
            vec![SpanArc { i: 0, k: 3, lat_ms: 1.0, imp: 1.0 }],
            vec![],
            vec![],
            vec![
                SpanArc { i: 1, k: 3, lat_ms: 1.0, imp: 9.0 }, // pruned-conv arc
                SpanArc { i: 1, k: 5, lat_ms: 2.0, imp: 1.0 }, // full-kernel arc
            ],
        ];
        let r = restrict_arcs(&sp, &arcs);
        assert_eq!(r[4].len(), 1);
        assert_eq!(r[4][0].k, 5);
        assert_eq!(r[1].len(), 1); // single-layer span: k == k_full trivially
    }
}
