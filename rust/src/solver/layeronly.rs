//! Eq. (8) — the LayerOnly baseline: latency-constrained layer pruning as
//! a 0-1 knapsack, solved exactly for discretized latencies in O(L·P).
//!
//! Items are reducible conv layers; keeping layer l costs its latency
//! T[l] and earns importance I[l] (how much the network suffers when l is
//! replaced by theta_id).  Irreducible layers (R) are forced in.

use std::collections::BTreeSet;

use crate::ir::Spec;

/// Per-layer knapsack input; index 0 unused (layers are 1-based).
#[derive(Debug, Clone)]
pub struct KnapsackInput {
    pub lat_ms: Vec<f64>,
    pub imp: Vec<f64>,
    /// forced[l] = layer must be kept (l in R).
    pub forced: Vec<bool>,
    pub budget_ms: f64,
    pub p: usize,
}

#[derive(Debug, Clone)]
pub struct KnapsackSolution {
    pub kept: BTreeSet<usize>,
    pub objective: f64,
    pub latency_est: f64,
}

pub fn solve(input: &KnapsackInput) -> Option<KnapsackSolution> {
    let l_max = input.lat_ms.len() - 1;
    let p = input.p;
    let unit = input.budget_ms / p as f64;
    if unit <= 0.0 {
        return None;
    }
    let disc = |ms: f64| (ms / unit).floor() as usize;

    // forced layers consume budget unconditionally
    let forced_cost: usize =
        (1..=l_max).filter(|&l| input.forced[l]).map(|l| disc(input.lat_ms[l])).sum();
    if forced_cost > p {
        return None;
    }
    let cap = p - forced_cost;

    const NEG: f64 = f64::NEG_INFINITY;
    let optional: Vec<usize> = (1..=l_max).filter(|&l| !input.forced[l]).collect();
    let n = optional.len();
    let mut best = vec![vec![NEG; cap + 1]; n + 1];
    let mut take = vec![vec![false; cap + 1]; n + 1];
    for t in 0..=cap {
        best[0][t] = 0.0;
    }
    for (t_i, &l) in optional.iter().enumerate() {
        let cost = disc(input.lat_ms[l]);
        for t in 0..=cap {
            let mut b = best[t_i][t]; // drop layer l
            if t >= cost && best[t_i][t - cost] != NEG {
                let v = best[t_i][t - cost] + input.imp[l];
                if v > b {
                    b = v;
                    take[t_i + 1][t] = true;
                }
            }
            best[t_i + 1][t] = b;
        }
    }
    // reconstruct at full capacity
    let mut kept: BTreeSet<usize> =
        (1..=l_max).filter(|&l| input.forced[l]).collect();
    let mut t = cap;
    for t_i in (0..n).rev() {
        if take[t_i + 1][t] {
            let l = optional[t_i];
            kept.insert(l);
            t -= disc(input.lat_ms[l]);
        }
    }
    let objective: f64 = kept
        .iter()
        .filter(|l| !input.forced[**l])
        .map(|&l| input.imp[l])
        .sum();
    let latency_est: f64 = kept.iter().map(|&l| input.lat_ms[l]).sum();
    Some(KnapsackSolution { kept, objective, latency_est })
}

/// Deployment spans for a LayerOnly solution: every layer stays its own
/// singleton span `(j-1, j, k)`; a conv dropped from `kept` (only gated
/// layers can be) deploys as the identity, recorded as `k = 1` so the
/// plan builder elides it.
pub fn deploy_spans(spec: &Spec, kept: &BTreeSet<usize>) -> Vec<(usize, usize, usize)> {
    (1..=spec.len())
        .map(|j| {
            let keep = kept.contains(&j) || !spec.conv(j).conv_gated;
            (j - 1, j, if keep { spec.conv(j).k } else { 1 })
        })
        .collect()
}

/// Kept interior activation boundaries for a LayerOnly solution: every
/// pristine (ungated) activation survives; gated ones survive iff their
/// conv does.  The final boundary L is never in A (sigma_L = id).
pub fn deploy_a(spec: &Spec, kept: &BTreeSet<usize>) -> Vec<usize> {
    (1..spec.len())
        .filter(|l| !spec.conv(*l).act_gated || kept.contains(l))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_res;
    use crate::util::rng::Rng;

    #[test]
    fn forced_layers_always_kept() {
        let input = KnapsackInput {
            lat_ms: vec![0.0, 1.0, 1.0, 1.0],
            imp: vec![0.0, 5.0, 1.0, 1.0],
            forced: vec![false, true, false, false],
            budget_ms: 2.0,
            p: 100,
        };
        let sol = solve(&input).unwrap();
        assert!(sol.kept.contains(&1));
        assert!(sol.latency_est <= 2.0 + 1e-9);
    }

    #[test]
    fn infeasible_when_forced_exceed_budget() {
        let input = KnapsackInput {
            lat_ms: vec![0.0, 3.0],
            imp: vec![0.0, 1.0],
            forced: vec![false, true],
            budget_ms: 2.0,
            p: 10,
        };
        assert!(solve(&input).is_none());
    }

    #[test]
    fn matches_bruteforce() {
        check_res("knapsack == bruteforce", 150, |r| gen(r), |input| {
            let got = solve(input).map(|s| s.objective);
            let want = brute(input);
            match (got, want) {
                (None, None) => Ok(()),
                (Some(g), Some(w)) if (g - w).abs() < 1e-9 => Ok(()),
                (g, w) => Err(format!("{g:?} vs {w:?}")),
            }
        });
    }

    fn gen(r: &mut Rng) -> KnapsackInput {
        let l = 1 + r.below(8);
        KnapsackInput {
            lat_ms: std::iter::once(0.0)
                .chain((0..l).map(|_| r.range(0.1, 1.5) as f64))
                .collect(),
            imp: std::iter::once(0.0)
                .chain((0..l).map(|_| r.uniform() * 2.0))
                .collect(),
            forced: std::iter::once(false)
                .chain((0..l).map(|_| r.uniform() < 0.3))
                .collect(),
            budget_ms: r.range(0.5, 4.0) as f64,
            p: 60 + r.below(60),
        }
    }

    #[test]
    fn deploy_spans_gate_dropped_layers_to_identity() {
        // toy spec: conv1 irreducible (conv_gated=false), conv2..4 gated
        let sp = crate::ir::tests::toy_spec();
        let kept: BTreeSet<usize> = [1usize, 2, 4].into_iter().collect();
        let spans = deploy_spans(&sp, &kept);
        assert_eq!(spans.len(), sp.len());
        for (j, &(i, jj, k)) in spans.iter().enumerate() {
            // every span is a singleton (j-1, j, _)
            assert_eq!((i, jj), (j, j + 1));
            let keep = kept.contains(&jj) || !sp.conv(jj).conv_gated;
            assert_eq!(k, if keep { sp.conv(jj).k } else { 1 }, "span {jj}");
        }
        // conv3 dropped -> identity (k = 1); conv2 kept -> its own kernel
        assert_eq!(spans[2], (2, 3, 1));
        assert_eq!(spans[1], (1, 2, sp.conv(2).k));
    }

    #[test]
    fn deploy_spans_force_irreducible_layers() {
        let sp = crate::ir::tests::toy_spec();
        // conv1 is irreducible: even absent from `kept` it keeps its kernel
        let kept: BTreeSet<usize> = BTreeSet::new();
        let spans = deploy_spans(&sp, &kept);
        assert_eq!(spans[0], (0, 1, sp.conv(1).k));
        for &(_, j, k) in &spans[1..] {
            assert_eq!(k, 1, "gated layer {j} must deploy as identity");
        }
    }

    #[test]
    fn deploy_a_keeps_pristine_and_kept_activations_only() {
        let sp = crate::ir::tests::toy_spec();
        // acts 1..3 are gated in the toy spec; 4 is the final boundary
        let kept: BTreeSet<usize> = [1usize, 3].into_iter().collect();
        assert_eq!(deploy_a(&sp, &kept), vec![1, 3]);
        // final boundary never appears even if "kept"
        let all: BTreeSet<usize> = (1..=sp.len()).collect();
        let a = deploy_a(&sp, &all);
        assert!(!a.contains(&sp.len()));
        assert_eq!(a, vec![1, 2, 3]);
    }

    fn brute(input: &KnapsackInput) -> Option<f64> {
        let l_max = input.lat_ms.len() - 1;
        let unit = input.budget_ms / input.p as f64;
        let disc = |ms: f64| (ms / unit).floor() as usize;
        let mut best = None;
        for mask in 0..(1u32 << l_max) {
            let mut ok = true;
            let mut cost = 0usize;
            let mut obj = 0.0;
            for l in 1..=l_max {
                let kept = mask & (1 << (l - 1)) != 0;
                if input.forced[l] && !kept {
                    ok = false;
                    break;
                }
                if kept {
                    cost += disc(input.lat_ms[l]);
                    if !input.forced[l] {
                        obj += input.imp[l];
                    }
                }
            }
            if ok && cost <= input.p && best.map_or(true, |b: f64| obj > b) {
                best = Some(obj);
            }
        }
        best
    }
}
