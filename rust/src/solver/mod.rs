//! Solvers — the paper's combinatorial core.
//!
//! * [`csel`]     — Eq. (3): exact subset-sum DP selecting which convs to
//!                  keep for a given merged kernel size (max l1-norm).
//! * [`dp`]       — Algorithm 1: the surrogate Problem (5) DP over
//!                  (layer, discretized latency budget).
//! * [`layeronly`]— Eq. (8): the 0-1 knapsack layer-pruning variant.
//! * [`depth`]    — Kim et al. 2023 baseline: activations only, C = [L]
//!                  (expressed as the k = k_max restriction of our tables).

pub mod csel;
pub mod depth;
pub mod dp;
pub mod layeronly;

use std::collections::BTreeSet;

/// A solved compression plan: the paper's (A*, C*, (k_i*)).
#[derive(Debug, Clone)]
pub struct Solution {
    /// Kept activation indices (ascending) — the set A*.
    pub a: Vec<usize>,
    /// Kept conv indices — the set C* (always contains R).
    pub c: BTreeSet<usize>,
    /// Merged spans (i, j, k): consecutive boundaries of {0} ∪ A* ∪ {L}
    /// with the chosen merged kernel size.
    pub spans: Vec<(usize, usize, usize)>,
    /// Objective value (sum of importance).
    pub objective: f64,
    /// Sum of table latencies (the surrogate latency estimate, ms).
    pub latency_est: f64,
}

impl Solution {
    pub fn summary(&self) -> String {
        format!(
            "A*={:?} |C*|={} spans={:?} obj={:.4} lat~{:.3}ms",
            self.a,
            self.c.len(),
            self.spans,
            self.objective,
            self.latency_est
        )
    }
}
