//! Eq. (3): the kept-conv selection \hat{C}_{ijk}.
//!
//! Among subsets C_ij ⊆ (i, j] with  1 + Σ_{l∈C_ij} inc(l) = k  and
//! R ∩ (i, j] ⊆ C_ij, keep the one maximizing Σ ||theta_l||_1.  Here
//! inc(l) = (Ker(theta_l) - 1) · stride_prefix (App. A dilation), so this
//! is an exact-sum knapsack solved by DP over (layer, kernel budget) —
//! "computing C~_ijk has a negligible cost" (Sec. 3.2).

use std::collections::BTreeSet;

use crate::ir::Spec;

/// l1 norms of each conv layer's weight, indexed by 1-based layer id.
pub fn layer_l1_norms(spec: &Spec, flat: &[f32]) -> Vec<f64> {
    let mut out = vec![0.0; spec.len() + 1];
    for c in &spec.convs {
        let w = spec.param_slice(flat, &format!("conv{}.w", c.idx));
        out[c.idx] = w.iter().map(|x| x.abs() as f64).sum();
    }
    out
}

/// Solve Eq. (3) exactly: returns the kept set achieving merged kernel
/// size exactly `k` over span (i, j], or None if `k` is unachievable.
pub fn select(
    spec: &Spec,
    l1: &[f64],
    i: usize,
    j: usize,
    k: usize,
) -> Option<BTreeSet<usize>> {
    let target = k.checked_sub(1)?;

    // forced (irreducible) layers contribute unconditionally
    let mut forced_sum = 0usize;
    let mut optional: Vec<(usize, usize)> = Vec::new(); // (layer, inc)
    let mut kept: BTreeSet<usize> = BTreeSet::new();
    for l in (i + 1)..=j {
        let inc = spec.k_increment(i, l);
        if !spec.conv(l).conv_gated {
            forced_sum += inc;
            kept.insert(l);
        } else {
            optional.push((l, inc));
        }
    }
    let rem = target.checked_sub(forced_sum)?;

    // DP over optional layers: best[s] = (sum_l1, chosen bitset path)
    // Reconstruct via parent pointers to keep memory linear in |optional|·rem.
    let n = optional.len();
    let mut best = vec![vec![f64::NEG_INFINITY; rem + 1]; n + 1];
    let mut take = vec![vec![false; rem + 1]; n + 1];
    best[0][0] = 0.0;
    for (t, &(l, inc)) in optional.iter().enumerate() {
        for s in 0..=rem {
            // skip layer l (replace by theta_id)
            let mut b = best[t][s];
            // keep layer l
            if s >= inc && best[t][s - inc] != f64::NEG_INFINITY {
                let v = best[t][s - inc] + l1[l];
                if v > b {
                    b = v;
                    take[t + 1][s] = true;
                }
            }
            best[t + 1][s] = b;
        }
    }
    if best[n][rem] == f64::NEG_INFINITY {
        return None;
    }
    // reconstruct
    let mut s = rem;
    for t in (0..n).rev() {
        if take[t + 1][s] {
            let (l, inc) = optional[t];
            kept.insert(l);
            s -= inc;
        }
    }
    debug_assert_eq!(s, 0);
    Some(kept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tests::toy_spec;
    use crate::util::prop::check_res;
    use crate::util::rng::Rng;

    fn norms(n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..=n).map(|_| rng.uniform() * 10.0).collect()
    }

    #[test]
    fn selects_exact_kernel_sum() {
        let sp = toy_spec();
        let l1 = vec![0.0, 1.0, 5.0, 2.0, 3.0];
        // span (1,4]: optional layers 2,3 (inc 2 each), 4 (inc 0)
        // k=3 -> keep exactly one of {2,3}; layer 2 has higher l1.
        let kept = select(&sp, &l1, 1, 4, 3).unwrap();
        assert!(kept.contains(&2) && !kept.contains(&3));
        // layer 4 has inc 0 and positive l1 -> keeping it is free mass
        assert!(kept.contains(&4));
    }

    #[test]
    fn unachievable_kernel_returns_none() {
        let sp = toy_spec();
        let l1 = vec![0.0; 5];
        assert!(select(&sp, &l1, 1, 4, 4).is_none()); // even k impossible
        assert!(select(&sp, &l1, 1, 4, 9).is_none()); // too large
    }

    #[test]
    fn forced_layers_always_kept() {
        let sp = toy_spec();
        let l1 = vec![0.0, 0.0, 1.0, 1.0, 1.0];
        // span (0,4] includes irreducible layer 1 (inc 2)
        for &k in &[3usize, 5, 7] {
            if let Some(kept) = select(&sp, &l1, 0, 4, k) {
                assert!(kept.contains(&1), "R ⊆ C violated at k={k}");
            }
        }
        assert!(select(&sp, &l1, 0, 4, 1).is_none(),
            "k=1 cannot drop the irreducible stem");
    }

    /// Exhaustive optimality check against brute force on the toy spec.
    #[test]
    fn matches_bruteforce() {
        let sp = toy_spec();
        check_res("csel == bruteforce", 200, |r| norms(4, r), |l1| {
            for (i, j) in [(0usize, 4usize), (1, 4), (1, 3), (3, 4)] {
                if !sp.valid_span(i, j) {
                    continue;
                }
                for k in sp.kernel_options(i, j) {
                    let got = select(&sp, l1, i, j, k);
                    let want = brute(&sp, l1, i, j, k);
                    match (got, want) {
                        (None, None) => {}
                        (Some(g), Some((wsum, _))) => {
                            let gsum: f64 =
                                g.iter().filter(|l| sp.conv(**l).conv_gated)
                                    .map(|l| l1[*l]).sum();
                            if (gsum - wsum).abs() > 1e-9 {
                                return Err(format!(
                                    "span ({i},{j}] k={k}: got {gsum} want {wsum}"));
                            }
                        }
                        (g, w) => {
                            return Err(format!(
                                "span ({i},{j}] k={k}: feasibility mismatch {g:?} vs {w:?}"))
                        }
                    }
                }
            }
            Ok(())
        });
    }

    fn brute(
        spec: &Spec,
        l1: &[f64],
        i: usize,
        j: usize,
        k: usize,
    ) -> Option<(f64, BTreeSet<usize>)> {
        let opts: Vec<usize> =
            ((i + 1)..=j).filter(|l| spec.conv(*l).conv_gated).collect();
        let forced: usize = ((i + 1)..=j)
            .filter(|l| !spec.conv(*l).conv_gated)
            .map(|l| spec.k_increment(i, l))
            .sum();
        let mut best: Option<(f64, BTreeSet<usize>)> = None;
        for mask in 0..(1u32 << opts.len()) {
            let mut sum = forced;
            let mut v = 0.0;
            let mut set = BTreeSet::new();
            for (t, &l) in opts.iter().enumerate() {
                if mask & (1 << t) != 0 {
                    sum += spec.k_increment(i, l);
                    v += l1[l];
                    set.insert(l);
                }
            }
            if 1 + sum == k && best.as_ref().map_or(true, |(b, _)| v > *b) {
                best = Some((v, set));
            }
        }
        best
    }
}
