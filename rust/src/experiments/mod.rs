//! Experiment drivers — one function per paper table/figure (DESIGN.md §5).
//! Each prints the table and records it into EXPERIMENTS.md.

pub mod figures;
pub mod tables;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::model::Manifest;
use crate::pipeline::{Pipeline, PipelineCfg};
use crate::runtime::Runtime;
use crate::serve::Engine;
use crate::tables::LatencyMode;

/// Shared experiment context: the deployment engine, output paths, and
/// the pipeline config.  `Ctx::new` opens the PJRT backend over an
/// artifacts directory; `Ctx::new_host` runs on the native host backend
/// (no artifacts, no XLA — `serve` / `profile` with `--backend host`).
pub struct Ctx {
    engine: Engine,
    pub repo: PathBuf,
    pub cfg: PipelineCfg,
}

/// Apply the env-driven config knobs (LM_FAST / LM_MEASURED /
/// LM_PRETRAIN / LM_FINETUNE) shared by every backend.
fn tune_cfg(mut cfg: PipelineCfg) -> PipelineCfg {
    // CI / quick mode can force the analytical latency model.
    // Explicit LM_PRETRAIN / LM_FINETUNE override the fast caps, and
    // LM_MEASURED (the `--measured` flag) pins measured latency even
    // under LM_FAST.
    if std::env::var("LM_FAST").is_ok() {
        cfg.build.mode = LatencyMode::Analytical;
        cfg.pretrain_steps = cfg.pretrain_steps.min(60);
        cfg.finetune_steps = cfg.finetune_steps.min(20);
        cfg.build.proxy_steps = cfg.build.proxy_steps.min(2);
        cfg.build.iters = cfg.build.iters.min(5);
        cfg.lat_iters = cfg.lat_iters.min(5);
    }
    if std::env::var("LM_MEASURED").is_ok() {
        cfg.build.mode = LatencyMode::Measured;
    }
    if let Ok(v) = std::env::var("LM_PRETRAIN") {
        if let Ok(n) = v.parse() {
            cfg.pretrain_steps = n;
        }
    }
    if let Ok(v) = std::env::var("LM_FINETUNE") {
        if let Ok(n) = v.parse() {
            cfg.finetune_steps = n;
        }
    }
    cfg
}

impl Ctx {
    pub fn new(artifacts: &std::path::Path, repo: PathBuf, cfg: PipelineCfg) -> Result<Ctx> {
        let rt = Arc::new(Runtime::new(artifacts)?);
        let man = Arc::new(Manifest::load(artifacts)?);
        Ok(Ctx { engine: Engine::new(rt, man), repo, cfg: tune_cfg(cfg) })
    }

    /// Context over the native host backend — no artifacts directory and
    /// no PJRT client; only deployment-side commands work.
    pub fn new_host(repo: PathBuf, cfg: PipelineCfg) -> Ctx {
        Ctx { engine: Engine::host(), repo, cfg: tune_cfg(cfg) }
    }

    /// Owning deployment handle (cheap clone).
    pub fn engine(&self) -> Engine {
        self.engine.clone()
    }

    /// The PJRT runtime (panics on a host-backend context).
    pub fn rt(&self) -> &Arc<Runtime> {
        self.engine.runtime()
    }

    /// The artifact manifest (panics on a host-backend context).
    pub fn man(&self) -> &Arc<Manifest> {
        self.engine.manifest()
    }

    pub fn experiments_md(&self) -> PathBuf {
        self.repo.join("EXPERIMENTS.md")
    }

    /// Suffix appended to table titles so EXPERIMENTS.md records which
    /// latency protocol produced each section.
    pub fn mode_tag(&self) -> &'static str {
        match self.cfg.build.mode {
            LatencyMode::Measured => " [measured latency]",
            LatencyMode::Analytical => " [fast mode: analytical latency]",
        }
    }

    pub fn pipeline(&self, model: &str) -> Result<Pipeline> {
        Pipeline::new(self.engine(), model, self.cfg.clone(), self.repo.clone())
    }
}
