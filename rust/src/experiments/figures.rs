//! Figure drivers (Figures 1-5 of the paper).

use anyhow::{Context, Result};

use super::Ctx;
use crate::bench::TableOut;
use crate::ir::Gates;
use crate::model::sig_str;
use crate::pipeline::{Method, Pipeline};
use crate::report;
use crate::runtime::measure;
use crate::train::{self, Gen};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Figure 1 — merged kernel growth vs latency: the motivating measurement.
/// We time the same (channels, resolution) conv at k = 1..K_MAX and report
/// per-layer latency next to the cumulative "merge n 3x3 layers" cost.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let mut t = TableOut::new(
        "Figure 1 — kernel size growth vs measured latency (b32, 32x32, 16ch)",
        &["Merged layers (3x3 each)", "Merged kernel", "Merged conv (ms)",
          "Unmerged chain (ms)", "Merging wins?"],
    );
    let (b, h, w, c) = (32, 32, 32, 16); // richest k-family in the manifest
    let mut rng = Rng::new(0xf19);
    let mut lat_k = |k: usize| -> Result<Option<f64>> {
        let sig = sig_str(b, h, w, c, c, k, 1, false);
        let Some(rel) = ctx.man().conv_art(&sig, "plain") else {
            return Ok(None); // kernel size unreachable by any model span
        };
        let exec = ctx.rt().load(&rel)?;
        let n = b * h * w * c;
        let x = Tensor::new(vec![b, h, w, c], (0..n).map(|_| rng.normal()).collect());
        let wt = Tensor::new(vec![c, c, k, k],
            (0..c * c * k * k).map(|_| rng.normal()).collect());
        let bias = Tensor::zeros(&[c]);
        Ok(Some(
            measure(&exec, &[&x, &wt, &bias], ctx.cfg.lat_warmup, ctx.cfg.lat_iters)?
                .p50_ms,
        ))
    };
    let l3 = lat_k(3)?.context("k=3 module must exist")?;
    for n in 1..=6usize {
        let k = 1 + 2 * n;
        if k > crate::ir::K_MAX {
            break;
        }
        let Some(merged) = lat_k(k)? else { continue };
        let chain = l3 * n as f64;
        t.row(vec![
            format!("{n}"),
            format!("{k}x{k}"),
            format!("{merged:.3}"),
            format!("{chain:.3}"),
            if merged < chain { "yes".into() } else { "NO — kernel blow-up".into() },
        ]);
    }
    t.print();
    report::record(&ctx.experiments_md(), "fig1", &t.markdown())?;
    Ok(())
}

/// Figure 2 — qualitative selection diagram: which activations and convs
/// LayerMerge keeps vs the Depth baseline, as ASCII.
pub fn fig2(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let budget = 0.6;
    let lm = pipe.solve(Method::LayerMerge, budget)?;
    let dp = pipe.solve(Method::Depth, budget)?;
    let spec = &pipe.model.spec;
    let render = |a: &[usize], c: &std::collections::BTreeSet<usize>| -> String {
        let aset: std::collections::BTreeSet<usize> = a.iter().copied().collect();
        let mut line_c = String::from("conv: ");
        let mut line_a = String::from("act : ");
        for l in 1..=spec.len() {
            let conv = spec.conv(l);
            line_c.push_str(if c.contains(&l) || !conv.conv_gated {
                if conv.depthwise { "D " } else { "C " }
            } else {
                ". "
            });
            line_a.push_str(if l == spec.len() {
                "  "
            } else if aset.contains(&l) {
                "| "
            } else {
                ". "
            });
        }
        format!("{line_c}\n{line_a}")
    };
    let body = format!(
        "### Figure 2 — qualitative selection @ {budget} budget (mnv2ish-1.0)\n\n\
         `C`/`D` = kept (dense/depthwise) conv, `.` = pruned; `|` = kept activation (merge boundary)\n\n\
         **LayerMerge (ours)** — {} merged layers, est {:.2} ms:\n```\n{}\n```\n\
         **Depth (Kim et al. 2023)** — {} merged layers, est {:.2} ms:\n```\n{}\n```\n",
        lm.spans.len(), lm.latency_est, render(&lm.a, &lm.c),
        dp.spans.len(), dp.latency_est, render(&dp.a, &dp.c),
    );
    println!("{body}");
    report::record(&ctx.experiments_md(), "fig2", &body)?;
    Ok(())
}

/// Figure 3 — test-metric recovery curves across fine-tuning.
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let budget = 0.65;
    let every = (ctx.cfg.finetune_steps / 8).max(1);
    let mut t = TableOut::new(
        "Figure 3 — recovery curves (eval accuracy vs fine-tune step, mnv2ish-1.0)",
        &["Step", "LayerMerge", "Depth", "LayerOnly"],
    );
    let mut curves = Vec::new();
    for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
        let sol = pipe.solve(m, budget)?;
        let a_set: std::collections::BTreeSet<usize> = sol.a.iter().copied().collect();
        let gates = pipe.model.spec.solution_gates(&a_set, &sol.c, &sol.spans);
        let mut params = pipe.pretrained.clone();
        let log = train::train(
            &pipe.model, &pipe.gen, &mut params, &gates,
            ctx.cfg.finetune_steps, ctx.cfg.finetune_lr, every,
        )?;
        curves.push(log.curve);
    }
    let steps: Vec<usize> = curves[0].iter().map(|c| c.0).collect();
    for (row_i, &s) in steps.iter().enumerate() {
        t.row(vec![
            format!("{s}"),
            format!("{:.2}", curves[0].get(row_i).map(|c| c.2 * 100.0).unwrap_or(0.0)),
            format!("{:.2}", curves[1].get(row_i).map(|c| c.2 * 100.0).unwrap_or(0.0)),
            format!("{:.2}", curves[2].get(row_i).map(|c| c.2 * 100.0).unwrap_or(0.0)),
        ]);
    }
    t.print();
    report::record(&ctx.experiments_md(), "fig3", &t.markdown())?;
    Ok(())
}

/// Figure 4 — KD recovery curve vs LayerMerge recovery curve.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let budget = 0.65;
    let every = (ctx.cfg.finetune_steps / 8).max(1);
    let sol = pipe.solve(Method::LayerMerge, budget)?;
    let a_set: std::collections::BTreeSet<usize> = sol.a.iter().copied().collect();
    let gates = pipe.model.spec.solution_gates(&a_set, &sol.c, &sol.spans);
    let mut params = pipe.pretrained.clone();
    let lm = train::train(&pipe.model, &pipe.gen, &mut params, &gates,
                          ctx.cfg.finetune_steps, ctx.cfg.finetune_lr, every)?;
    // KD-from-scratch curve on the student (same step budget)
    let student = ctx.engine().load_model("mnv2ish-0.75")?;
    let sgen = Gen::for_model(&student, ctx.cfg.seed ^ 0xda7a);
    let sgates = student.spec.pristine_gates();
    let mut sparams = student.init.clone();
    let slog = train::train(&student, &sgen, &mut sparams, &sgates,
                            ctx.cfg.finetune_steps, ctx.cfg.pretrain_lr, every)?;
    let mut t = TableOut::new(
        "Figure 4 — recovery: LayerMerge fine-tune vs small-net training",
        &["Step", "LayerMerge-65%", "mnv2ish-0.75 from scratch"],
    );
    for (i, c) in lm.curve.iter().enumerate() {
        t.row(vec![
            format!("{}", c.0),
            format!("{:.2}", c.2 * 100.0),
            format!("{:.2}", slog.curve.get(i).map(|x| x.2 * 100.0).unwrap_or(0.0)),
        ]);
    }
    t.print();
    report::record(&ctx.experiments_md(), "fig4", &t.markdown())?;
    Ok(())
}

/// Figure 5 — Pareto curves: metric vs measured speed-up per method.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let budgets = [0.85, 0.75, 0.65, 0.55, 0.45];
    let mut body = String::from("### Figure 5 — Pareto curves (eager-format speed-up)\n");
    for model in ["resnetish", "mnv2ish-1.0"] {
        let mut pipe = ctx.pipeline(model)?;
        let mut t = TableOut::new(
            &format!("Pareto — {model}"),
            &["Method", "Budget", "Acc (%)", "Speed-up"],
        );
        for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
            for &b in &budgets {
                match pipe.solve(m, b).and_then(|sol| {
                    pipe.finetune_and_deploy(m, b, &sol, None, false)
                }) {
                    Ok(c) => t.row(vec![
                        m.name().into(),
                        format!("{b:.2}"),
                        format!("{:.2}", c.merged_metric * 100.0),
                        format!("{:.2}x", pipe.orig_lat_eager / c.lat_eager_ms),
                    ]),
                    Err(_) => {}
                }
            }
        }
        t.print();
        body.push_str(&t.markdown());
    }
    report::record(&ctx.experiments_md(), "fig5", &body)?;
    Ok(())
}

/// FDD of a (params, gates) configuration: DDIM-sample a batch from the
/// gated graph and compare resnetish-embedder stats against clean data.
pub fn fdd_of_gates(
    ctx: &Ctx,
    pipe: &Pipeline,
    params: &[f32],
    gates: &Gates,
) -> Result<f64> {
    let spec = &pipe.model.spec;
    let dg = match &pipe.gen {
        Gen::Diffusion(d) => d.clone(),
        _ => anyhow::bail!("fdd needs the diffusion model"),
    };
    // DDIM sampling with 8 steps on the gated graph
    let b = spec.batch;
    let mut rng = Rng::new(0x5a3e);
    let n = b * spec.h * spec.w * spec.c;
    let mut xt = Tensor::new(vec![b, spec.h, spec.w, spec.c],
        (0..n).map(|_| rng.normal()).collect());
    let steps = 8usize;
    let tmax = dg.t_max as f32;
    for s in (1..=steps).rev() {
        let t_cur = tmax * s as f32 / steps as f32 - 1.0;
        let t_prev = (tmax * (s - 1) as f32 / steps as f32 - 1.0).max(0.0);
        let tt = Tensor::full(&[b], t_cur.max(0.0));
        let ab_t = Tensor::full(&[b], dg.abar(t_cur.max(0.0)));
        let ab_p = Tensor::full(&[b], dg.abar(t_prev));
        xt = pipe.model.sample_step(params, gates, &xt, &tt, &ab_t, &ab_p)?;
    }
    // embed generated + real through the resnetish embedder
    let emb_model = ctx.engine().load_model("resnetish")?;
    let emb_pre = ctx.repo.join("cache").join(format!(
        "resnetish.pretrained.s{}.bin", ctx.cfg.pretrain_steps));
    let emb_params = if emb_pre.exists() {
        Tensor::read_f32_file(&emb_pre)?
    } else {
        emb_model.init.clone()
    };
    let eg = emb_model.spec.pristine_gates();
    // resize 16x16 samples up to the embedder's 32x32 input (nearest)
    let up = |t: &Tensor| -> Tensor {
        let (bb, h, w, c) = (t.dims[0], t.dims[1], t.dims[2], t.dims[3]);
        let (fh, fw) = (emb_model.spec.h / h, emb_model.spec.w / w);
        let mut out = Tensor::zeros(&[bb, h * fh, w * fw, c]);
        for n2 in 0..bb {
            for i in 0..h * fh {
                for j in 0..w * fw {
                    for cc in 0..c {
                        let v = t.at4(n2, i / fh, j / fw, cc);
                        out.set4(n2, i, j, cc, v);
                    }
                }
            }
        }
        out
    };
    let gen_feats = emb_model.embed(&emb_params, &eg, &up(&xt))?;
    // real batch
    let real = match dg.batch(train::STREAM_EVAL, 0) {
        crate::model::Batch::Diffusion { x0, .. } => x0,
        _ => unreachable!(),
    };
    let real_feats = emb_model.embed(&emb_params, &eg, &up(&real))?;
    Ok(crate::train::metrics::fdd(&real_feats, &gen_feats))
}

pub fn all(ctx: &Ctx) -> Result<()> {
    fig1(ctx)?;
    fig2(ctx)?;
    fig3(ctx)?;
    fig4(ctx)?;
    fig5(ctx)?;
    Ok(())
}
