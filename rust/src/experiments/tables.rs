//! Table drivers (Tables 1-11 of the paper).

use anyhow::{Context, Result};

use super::Ctx;
use crate::baselines::{channel, sequential};
use crate::bench::TableOut;
use crate::ir::Task;
use crate::pipeline::{Compressed, Method, Pipeline};
use crate::report;
use crate::train;

/// Budget fractions (T0 / T_orig) per compression level; chosen to produce
/// paper-comparable speed-up ranges on this testbed.
pub const BUDGETS_CLS: [f64; 3] = [0.8, 0.65, 0.5];
pub const BUDGETS_DDPM: [f64; 3] = [0.9, 0.8, 0.65];

fn push_rows(
    t: &mut TableOut,
    pipe: &Pipeline,
    results: &[Compressed],
    classify: bool,
) {
    t.row(vec![
        pipe.model.name.clone(),
        if classify {
            format!("{:.2}", pipe.orig_metric * 100.0)
        } else {
            format!("{:.4}", -pipe.orig_metric)
        },
        "1.00x".into(),
        "1.00x".into(),
        format!("{}", pipe.model.spec.len()),
        "0.00".into(),
    ]);
    for c in results {
        t.row(report::row(
            c,
            pipe.orig_metric,
            pipe.orig_lat_eager,
            pipe.orig_lat_fused,
            classify,
        ));
    }
}

/// Generic classification compression table (Tables 1-3 pattern): every
/// method at every budget, plus the channel-pruning reference.
pub fn classification_table(
    ctx: &Ctx,
    id: &str,
    title: &str,
    model: &str,
    budgets: &[f64],
) -> Result<()> {
    let title = format!("{title}{}", ctx.mode_tag());
    let mut pipe = ctx.pipeline(model)?;
    let mut results = Vec::new();
    for &b in budgets {
        for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
            match pipe.solve_relaxed(m, b).and_then(|(sol, b_used)| {
                pipe.finetune_and_deploy(m, b_used, &sol, None, false)
            }) {
                Ok(c) => results.push(c),
                Err(e) => eprintln!("[{id}] {} @{b}: {e:#}", m.name()),
            }
        }
    }
    // channel-pruning reference (HALP-style) at the middle budget
    let halp = channel_reference(&mut pipe, budgets[budgets.len() / 2])?;
    let mut t = report::compression_table(&title, true);
    push_rows(&mut t, &pipe, &results, true);
    t.row(halp);
    t.print();
    report::record(&ctx.experiments_md(), id, &t.markdown())?;
    Ok(())
}

/// HALP-style channel-pruning row: masked fine-tune for accuracy,
/// analytical latency for the sliced network (DESIGN.md §2).
fn channel_reference(pipe: &mut Pipeline, budget: f64) -> Result<Vec<String>> {
    let spec = pipe.model.spec.clone();
    let plan = channel::solve_halp(&spec, &pipe.pretrained, budget, pipe.cfg.p_disc);
    let masks = channel::masks(&spec, &pipe.pretrained, &plan);
    let (_, metric) = channel::finetune_masked(
        &pipe.model, &pipe.gen, &pipe.pretrained, &masks,
        pipe.cfg.finetune_steps, pipe.cfg.finetune_lr, pipe.cfg.eval_batches,
    )?;
    let full: f64 = (1..=spec.len())
        .map(|l| channel::layer_latency(&spec, l, 1.0, 1.0))
        .sum();
    let speedup = full / plan.latency_ms;
    Ok(vec![
        format!("HALP-{:.0}% (channel ref)", budget * 100.0),
        format!("{:.2}", metric * 100.0),
        format!("{speedup:.2}x*"),
        format!("{speedup:.2}x*"),
        format!("{}", spec.len()),
        format!("{:.2}", (metric - pipe.orig_metric) * 100.0),
    ])
}

pub fn table1(ctx: &Ctx) -> Result<()> {
    classification_table(
        ctx, "table1",
        "Table 1 — resnetish (ResNet-34 analogue) on synthetic classification",
        "resnetish", &BUDGETS_CLS,
    )
}

pub fn table2(ctx: &Ctx) -> Result<()> {
    classification_table(
        ctx, "table2",
        "Table 2 — mnv2ish-1.0 (MobileNetV2-1.0 analogue)",
        "mnv2ish-1.0", &BUDGETS_CLS,
    )
}

pub fn table3(ctx: &Ctx) -> Result<()> {
    classification_table(
        ctx, "table3",
        "Table 3 — mnv2ish-1.4 (MobileNetV2-1.4 analogue)",
        "mnv2ish-1.4", &BUDGETS_CLS,
    )
}

/// Table 4 — DDPM compression: diffusion loss (Perf proxy) + FDD vs speed-up.
pub fn table4(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("ddpmish")?;
    let mut t = TableOut::new(
        "Table 4 — ddpmish (DDPM analogue) on the synthetic image manifold",
        &["Network", "DiffLoss ↓", "FDD ↓", "Eager Speed-up ↑", "Fused Speed-up ↑", "Depth"],
    );
    let fdd0 = super::figures::fdd_of_gates(
        ctx, &pipe, &pipe.pretrained.clone(), &pipe.model.spec.pristine_gates(),
    )?;
    t.row(vec![
        "ddpmish".into(),
        format!("{:.4}", -pipe.orig_metric),
        format!("{fdd0:.3}"),
        "1.00x".into(), "1.00x".into(),
        format!("{}", pipe.model.spec.len()),
    ]);
    for &b in &BUDGETS_DDPM {
        for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
            match pipe.solve(m, b).and_then(|sol| {
                pipe.finetune_and_deploy(m, b, &sol, None, false)
            }) {
                Ok(c) => {
                    let fdd = super::figures::fdd_of_gates(
                        ctx, &pipe, &c.finetuned, &c.gates,
                    )?;
                    t.row(vec![
                        format!("{}-{:.0}%", c.method, b * 100.0),
                        format!("{:.4}", -c.merged_metric),
                        format!("{fdd:.3}"),
                        format!("{:.2}x", pipe.orig_lat_eager / c.lat_eager_ms),
                        format!("{:.2}x", pipe.orig_lat_fused / c.lat_fused_ms),
                        format!("{}", c.depth),
                    ]);
                }
                Err(e) => eprintln!("[table4] {} @{b}: {e:#}", m.name()),
            }
        }
    }
    t.print();
    report::record(&ctx.experiments_md(), "table4", &t.markdown())?;
    Ok(())
}

/// Table 5 — channel-pruned DDPM (Diff-style) combined with depth methods.
pub fn table5(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("ddpmish")?;
    let spec = pipe.model.spec.clone();
    let mut t = TableOut::new(
        "Table 5 — Diff-style channel pruning + depth compression on ddpmish",
        &["Network", "DiffLoss ↓", "Est. Speed-up ↑", "Depth"],
    );
    t.row(vec!["ddpmish".into(), format!("{:.4}", -pipe.orig_metric),
               "1.00x".into(), format!("{}", spec.len())]);
    // Diff-style uniform channel pruning
    let cplan = channel::solve_uniform(&spec, &pipe.pretrained, 0.6);
    let masks = channel::masks(&spec, &pipe.pretrained, &cplan);
    let (masked_params, metric) = channel::finetune_masked(
        &pipe.model, &pipe.gen, &pipe.pretrained, &masks,
        pipe.cfg.finetune_steps, pipe.cfg.finetune_lr, pipe.cfg.eval_batches,
    )?;
    let full: f64 = (1..=spec.len())
        .map(|l| channel::layer_latency(&spec, l, 1.0, 1.0))
        .sum();
    let ch_scale = full / cplan.latency_ms;
    t.row(vec![
        "Diff-60% (channel)".into(),
        format!("{:.4}", -metric),
        format!("{ch_scale:.2}x*"),
        format!("{}", spec.len()),
    ]);
    // depth methods on top of the channel-pruned weights: swap the
    // pipeline's pretrained for the masked checkpoint and re-run.
    pipe.pretrained = masked_params;
    pipe.tables = None; // rebuild importance on the masked model
    for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
        let b = 0.8;
        match pipe.solve(m, b).and_then(|sol| {
            pipe.finetune_and_deploy(m, b, &sol, None, false)
        }) {
            Ok(c) => {
                let depth_speed = pipe.orig_lat_eager / c.lat_eager_ms;
                t.row(vec![
                    format!("Diff-60% -> {}-{:.0}%", c.method, b * 100.0),
                    format!("{:.4}", -c.merged_metric),
                    format!("{:.2}x*", depth_speed * ch_scale),
                    format!("{}", c.depth),
                ]);
            }
            Err(e) => eprintln!("[table5] {}: {e:#}", m.name()),
        }
    }
    t.print();
    report::record(&ctx.experiments_md(), "table5", &t.markdown())?;
    Ok(())
}

/// Table 6 — joint (LayerMerge) vs sequential (Depth -> LayerOnly).
pub fn table6(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let mut t = report::compression_table(
        "Table 6 — joint vs sequential optimization (mnv2ish-1.0)", true);
    let mut results = Vec::new();
    for &(p1, p2, joint) in &[(0.8, 0.8, 0.64), (0.8, 0.65, 0.52)] {
        match sequential::run(&mut pipe, p1, p2) {
            Ok(c) => results.push(c),
            Err(e) => eprintln!("[table6] sequential {p1}x{p2}: {e:#}"),
        }
        let m = Method::LayerMerge;
        match pipe.solve(m, joint).and_then(|sol| {
            pipe.finetune_and_deploy(m, joint, &sol, None, false)
        }) {
            Ok(c) => results.push(c),
            Err(e) => eprintln!("[table6] joint @{joint}: {e:#}"),
        }
    }
    push_rows(&mut t, &pipe, &results, true);
    t.print();
    report::record(&ctx.experiments_md(), "table6", &t.markdown())?;
    Ok(())
}

/// Table 7 — wall-clock for constructing the lookup tables per model.
pub fn table7(ctx: &Ctx) -> Result<()> {
    let mut t = TableOut::new(
        "Table 7 — lookup-table construction wall-clock (this testbed)",
        &["Network", "Importance table (s)", "Latency table (s)", "# entries"],
    );
    for model in ["resnetish", "mnv2ish-1.0", "mnv2ish-1.4", "ddpmish"] {
        let mut pipe = match ctx.pipeline(model) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("[table7] {model}: {e:#}");
                continue;
            }
        };
        let tb = pipe.ensure_tables()?;
        t.row(vec![
            model.into(),
            format!("{:.1}", tb.imp_build_s),
            format!("{:.1}", tb.lat_build_s),
            format!("{}", tb.entries.len()),
        ]);
    }
    t.print();
    report::record(&ctx.experiments_md(), "table7", &t.markdown())?;
    Ok(())
}

/// Table 8 — importance-table cost: Depth vs LayerOnly vs LayerMerge.
/// Depth needs only the k_full entries, LayerOnly only per-layer entries;
/// LayerMerge pays for the full (i, j, k) family (but each entry is cheap
/// — the point of App. C Table 8).
pub fn table8(ctx: &Ctx) -> Result<()> {
    let mut t = TableOut::new(
        "Table 8 — importance-table size per method",
        &["Model", "Method", "# table entries", "est. build share"],
    );
    for model in ["resnetish", "mnv2ish-1.0"] {
        let mut pipe = ctx.pipeline(model)?;
        let spec = pipe.model.spec.clone();
        let tb = pipe.ensure_tables()?;
        let total = tb.entries.len();
        let depth_entries = tb
            .entries
            .keys()
            .filter(|&&(i, j, k)| k == crate::solver::depth::k_full(&spec, i, j))
            .count();
        let layeronly_entries = spec.convs.iter().filter(|c| c.conv_gated).count();
        for (m, n) in [
            ("Depth (Kim et al. 2023)", depth_entries),
            ("LayerOnly (ours)", layeronly_entries),
            ("LayerMerge (ours)", total),
        ] {
            t.row(vec![
                model.into(),
                m.into(),
                format!("{n}"),
                format!("{:.0}%", 100.0 * n as f64 / total.max(1) as f64),
            ]);
        }
    }
    t.print();
    report::record(&ctx.experiments_md(), "table8", &t.markdown())?;
    Ok(())
}

/// Table 9 — effect of shorter fine-tuning budgets (90/30/20-epoch analogue:
/// full / one-third / one-fifth of the step budget).
pub fn table9(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let full = pipe.cfg.finetune_steps;
    let mut t = report::compression_table(
        "Table 9 — shorter fine-tuning (steps analogue of 90/30/20 epochs)", true);
    let mut results = Vec::new();
    for steps in [full, full / 3, full / 5] {
        for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
            let b = 0.65;
            match pipe.solve(m, b).and_then(|sol| {
                pipe.finetune_and_deploy(m, b, &sol, Some(steps.max(1)), false)
            }) {
                Ok(mut c) => {
                    c.method = format!("{} ({}st)", c.method, steps.max(1));
                    results.push(c);
                }
                Err(e) => eprintln!("[table9] {} {steps}: {e:#}", m.name()),
            }
        }
    }
    push_rows(&mut t, &pipe, &results, true);
    t.print();
    report::record(&ctx.experiments_md(), "table9", &t.markdown())?;
    Ok(())
}

/// Table 10 — knowledge distillation into a smaller net vs LayerMerge.
pub fn table10(ctx: &Ctx) -> Result<()> {
    let teacher_pipe = ctx.pipeline("mnv2ish-1.0")?;
    let student = ctx.engine().load_model("mnv2ish-0.75")?;
    let rel = ctx
        .man()
        .json
        .req("kd")
        .get("mnv2ish-0.75_from_1.0")
        .and_then(|j| j.as_str())
        .context("kd artifact missing (needs mnv2ish-1.0 + -0.75 in aot)")?
        .to_string();
    let kd = ctx.rt().load(&rel)?;

    // KD training loop: teacher weights fixed, student trained from scratch
    // (the paper's point: same budget, distillation must train from init).
    let gen = train::Gen::for_model(&student, ctx.cfg.seed ^ 0xda7a);
    let mut sparams = student.init.clone();
    let mut smom = vec![0.0f32; sparams.len()];
    let steps = ctx.cfg.pretrain_steps; // same budget as pretraining
    let tflat = crate::util::tensor::Tensor::new(
        vec![teacher_pipe.pretrained.len()], teacher_pipe.pretrained.clone());
    for s in 0..steps {
        let batch = gen.batch(train::STREAM_TRAIN, s as u64);
        let (x, y) = match &batch {
            crate::model::Batch::Classify { x, y } => (x.clone(), y.clone()),
            _ => unreachable!(),
        };
        let lr = train::cosine_lr(ctx.cfg.pretrain_lr, s, steps);
        let p = crate::util::tensor::Tensor::new(vec![sparams.len()],
            std::mem::take(&mut sparams));
        let m = crate::util::tensor::Tensor::new(vec![smom.len()],
            std::mem::take(&mut smom));
        let lrt = crate::util::tensor::Tensor::scalar(lr);
        let out = kd.run(&[&tflat, &p, &m, &x, &y, &lrt])?;
        let mut it = out.into_iter();
        sparams = it.next().unwrap().data;
        smom = it.next().unwrap().data;
    }
    let sgates = student.spec.pristine_gates();
    let (_, kd_acc) = train::evaluate(&student, &gen, &sparams, &sgates,
                                      ctx.cfg.eval_batches)?;
    let splan = std::sync::Arc::new(crate::exec::Plan::original(&student.spec, &sparams)?);
    let slat = ctx.engine().measure(&splan, crate::exec::Format::Eager,
                                    ctx.cfg.lat_warmup, ctx.cfg.lat_iters)?.p50_ms;

    let mut pipe = teacher_pipe;
    let mut t = report::compression_table(
        "Table 10 — KD into mnv2ish-0.75 vs LayerMerge on mnv2ish-1.0", true);
    let mut results = Vec::new();
    let m = Method::LayerMerge;
    if let Ok(sol) = pipe.solve(m, 0.65) {
        if let Ok(c) = pipe.finetune_and_deploy(m, 0.65, &sol, None, false) {
            results.push(c);
        }
    }
    push_rows(&mut t, &pipe, &results, true);
    t.row(vec![
        "KD (mnv2ish-0.75 student)".into(),
        format!("{:.2}", kd_acc * 100.0),
        format!("{:.2}x", pipe.orig_lat_eager / slat),
        "-".into(),
        format!("{}", student.spec.len()),
        format!("{:.2}", (kd_acc - pipe.orig_metric) * 100.0),
    ]);
    t.print();
    report::record(&ctx.experiments_md(), "table10", &t.markdown())?;
    Ok(())
}

/// Table 11 — applying KD *during* pruned-network fine-tuning.
pub fn table11(ctx: &Ctx) -> Result<()> {
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;
    let mut t = report::compression_table(
        "Table 11 — KD-assisted fine-tuning of pruned mnv2ish-1.0", true);
    let mut results = Vec::new();
    for &b in &[0.8, 0.65] {
        for m in [Method::LayerMerge, Method::Depth, Method::LayerOnly] {
            match pipe.solve(m, b).and_then(|sol| {
                pipe.finetune_and_deploy(m, b, &sol, None, true) // distill=true
            }) {
                Ok(mut c) => {
                    c.method = format!("{} +KD", c.method);
                    results.push(c);
                }
                Err(e) => eprintln!("[table11] {} @{b}: {e:#}", m.name()),
            }
        }
    }
    push_rows(&mut t, &pipe, &results, true);
    t.print();
    report::record(&ctx.experiments_md(), "table11", &t.markdown())?;
    Ok(())
}

/// All-tables convenience driver.
pub fn all(ctx: &Ctx) -> Result<()> {
    table1(ctx)?;
    table2(ctx)?;
    table3(ctx)?;
    table4(ctx)?;
    table5(ctx)?;
    table6(ctx)?;
    table7(ctx)?;
    table8(ctx)?;
    table9(ctx)?;
    table10(ctx)?;
    table11(ctx)?;
    Ok(())
}

#[allow(unused)]
fn unused_task_guard(t: Task) {
    match t {
        Task::Classify | Task::Diffusion => {}
    }
}
