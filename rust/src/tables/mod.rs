//! Lookup-table construction — the paper's Sec. 3.2 machinery.
//!
//! * Latency table T[i,j,k]: wall-clock of the merged layer's conv module,
//!   measured through PJRT with the warm-up/average protocol (App. C), or
//!   an analytical roofline model (fast mode / CI).
//! * Importance table I[i,j,k] (Eq. 4): fine-tune the gated network for a
//!   few steps with the (A~_ij, C~_ijk) gate configuration on a proxy data
//!   stream, evaluate, and exponentiate the perf delta.
//! * Per-layer tables for the LayerOnly baseline (Eq. 8).
//!
//! Construction is embarrassingly parallel (the paper parallelizes across
//! GPUs; we fan out across a thread pool sharing the PJRT client) and the
//! result is cached to JSON keyed by a parameter-vector fingerprint.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ir::Spec;
use crate::model::{sig_str, Manifest, Model};
use crate::runtime::measure;
use crate::solver::csel;
use crate::solver::dp::SpanArc;
use crate::train::{proxy_perf, Gen};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// One (i, j, k) table entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub lat_ms: f64,
    pub imp: f64,
    /// \hat{C}_{ijk} — the kept convs realizing kernel size k (Eq. 3).
    pub kept: BTreeSet<usize>,
}

#[derive(Debug, Clone)]
pub struct Tables {
    pub model: String,
    pub entries: BTreeMap<(usize, usize, usize), Entry>,
    /// Per-original-layer latency (1-based; [0] unused).
    pub layer_lat: Vec<f64>,
    /// Keep-importance per layer for LayerOnly (1-based).
    pub layer_imp: Vec<f64>,
    /// Latency of everything outside the merged-conv sum: head, attention,
    /// upsample, norm and unfolded residual adds (sum-approximation, Sec 3.2).
    pub fixed_ms: f64,
    pub base_perf: f64,
    pub lat_build_s: f64,
    pub imp_build_s: f64,
}

impl Tables {
    /// Original-model latency estimate under the same sum approximation.
    pub fn orig_ms(&self) -> f64 {
        self.layer_lat.iter().sum::<f64>() + self.fixed_ms
    }

    /// Arc set for Algorithm 1 (and, restricted, the Depth baseline).
    pub fn arcs(&self, l_max: usize) -> Vec<Vec<SpanArc>> {
        let mut arcs = vec![Vec::new(); l_max + 1];
        for (&(i, j, k), e) in &self.entries {
            arcs[j].push(SpanArc { i, k, lat_ms: e.lat_ms, imp: e.imp });
        }
        arcs
    }

    // ---------------- cache ------------------------------------------------

    pub fn cache_path(root: &Path, model: &str, mode: LatencyMode) -> PathBuf {
        root.join("cache").join(format!("{model}.{}.tables.json", mode.tag()))
    }

    pub fn save(&self, path: &Path, fingerprint: u64) -> Result<()> {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|(&(i, j, k), e)| {
                Json::obj(vec![
                    ("i", Json::num(i as f64)),
                    ("j", Json::num(j as f64)),
                    ("k", Json::num(k as f64)),
                    ("lat", Json::num(e.lat_ms)),
                    ("imp", Json::num(e.imp)),
                    (
                        "kept",
                        Json::Arr(e.kept.iter().map(|&l| Json::num(l as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("fingerprint", Json::num(fingerprint as f64)),
            ("entries", Json::Arr(entries)),
            (
                "layer_lat",
                Json::Arr(self.layer_lat.iter().map(|&v| Json::num(v)).collect()),
            ),
            (
                "layer_imp",
                Json::Arr(self.layer_imp.iter().map(|&v| Json::num(v)).collect()),
            ),
            ("fixed_ms", Json::num(self.fixed_ms)),
            ("base_perf", Json::num(self.base_perf)),
            ("lat_build_s", Json::num(self.lat_build_s)),
            ("imp_build_s", Json::num(self.imp_build_s)),
        ]);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path, expect_fingerprint: u64) -> Option<Tables> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.req("fingerprint").as_f64()? as u64 != expect_fingerprint {
            return None;
        }
        let mut entries = BTreeMap::new();
        for e in j.req("entries").as_arr()? {
            let key = (
                e.req("i").as_usize()?,
                e.req("j").as_usize()?,
                e.req("k").as_usize()?,
            );
            entries.insert(
                key,
                Entry {
                    lat_ms: e.req("lat").as_f64()?,
                    imp: e.req("imp").as_f64()?,
                    kept: e
                        .req("kept")
                        .as_arr()?
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                },
            );
        }
        Some(Tables {
            model: j.req("model").as_str()?.to_string(),
            entries,
            layer_lat: j
                .req("layer_lat")
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            layer_imp: j
                .req("layer_imp")
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            fixed_ms: j.req("fixed_ms").as_f64()?,
            base_perf: j.req("base_perf").as_f64()?,
            lat_build_s: j.req("lat_build_s").as_f64()?,
            imp_build_s: j.req("imp_build_s").as_f64()?,
        })
    }
}

/// FNV-1a over the pretrained parameter bytes — cache key.
pub fn fingerprint(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in params {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyMode {
    /// Real wall-clock through PJRT (the paper's protocol).
    Measured,
    /// FLOPs + dispatch-overhead roofline model (fast mode / tests).
    Analytical,
}

impl LatencyMode {
    pub fn tag(&self) -> &'static str {
        match self {
            LatencyMode::Measured => "measured",
            LatencyMode::Analytical => "analytical",
        }
    }
}

/// Builder knobs; the defaults match the scaled-down App. C protocol.
#[derive(Debug, Clone)]
pub struct BuildCfg {
    pub mode: LatencyMode,
    pub warmup: usize,
    pub iters: usize,
    /// Fine-tune steps per importance entry ("a few steps", App. C).
    pub proxy_steps: usize,
    pub proxy_lr: f32,
    pub eval_batches: usize,
    pub workers: usize,
    /// Skip the on-disk cache and rebuild from scratch (`--force`).
    pub force: bool,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg {
            mode: LatencyMode::Measured,
            warmup: 5,
            iters: 30,
            proxy_steps: 8,
            proxy_lr: 0.01,
            eval_batches: 2,
            workers: 1,
            force: false,
        }
    }
}

/// Analytical per-op latency: max(compute, bandwidth) + dispatch overhead.
/// Calibrated once against CPU-XLA convs; the *shape* (k^2 growth, per-op
/// overhead rewarding depth reduction) is what the solver consumes.
pub fn analytical_conv_ms(
    b: usize,
    h: usize,
    w: usize,
    ci: usize,
    co: usize,
    k: usize,
    s: usize,
    dw: bool,
) -> f64 {
    let (ho, wo) = (h.div_ceil(s), w.div_ceil(s));
    let flops = if dw {
        2.0 * (b * ho * wo * co * k * k) as f64
    } else {
        2.0 * (b * ho * wo * co * ci * k * k) as f64
    };
    let bytes = 4.0 * (b * h * w * ci + b * ho * wo * co + co * ci * k * k) as f64;
    const GFLOPS: f64 = 40.0e9; // effective CPU-XLA conv throughput
    const GBPS: f64 = 25.0e9;
    const DISPATCH_MS: f64 = 0.05;
    (flops / GFLOPS).max(bytes / GBPS) * 1e3 + DISPATCH_MS
}

/// Measure (or model) one conv signature's latency.
fn conv_latency(
    model: &Model,
    man: &Manifest,
    cfg: &BuildCfg,
    b: usize,
    h: usize,
    w: usize,
    ci: usize,
    co: usize,
    k: usize,
    s: usize,
    dw: bool,
    act: &str,
) -> Result<f64> {
    if cfg.mode == LatencyMode::Analytical {
        return Ok(analytical_conv_ms(b, h, w, ci, co, k, s, dw));
    }
    // Measure the `plain` module — the op the Eager ("PyTorch format")
    // deployment actually dispatches.  (On XLA-CPU the act-fused variants
    // compile to loop fusions that bypass the fast Eigen conv path, which
    // would skew T against exactly the layers the solver merges.)
    let _ = act;
    let sig = sig_str(b, h, w, ci, co, k, s, dw);
    let rel = man
        .conv_art(&sig, "plain")
        .with_context(|| format!("no conv artifact for {sig}"))?;
    let exec = model.rt.load(&rel)?;
    let mut rng = Rng::new(0x1a7e ^ (k as u64) << 8 ^ ci as u64);
    let x = rand_tensor(&mut rng, &[b, h, w, ci]);
    let wgt = rand_tensor(&mut rng, &[co, if dw { 1 } else { ci }, k, k]);
    let bias = rand_tensor(&mut rng, &[co]);
    let stats = measure(&exec, &[&x, &wgt, &bias], cfg.warmup, cfg.iters)?;
    Ok(stats.p50_ms)
}

fn rand_tensor(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

/// Fixed (non-conv) latency of a model: head / attention / upsample /
/// group-norm / residual-add ops, summed once.
fn fixed_latency(model: &Model, man: &Manifest, cfg: &BuildCfg) -> Result<f64> {
    let sp = &model.spec;
    let b = sp.batch;
    if cfg.mode == LatencyMode::Analytical {
        // ops are bandwidth-bound elementwise kernels
        let mut ms = 0.0;
        for c in &sp.convs {
            let bytes = 4.0 * (b * c.h_out() * c.w_out() * c.cout) as f64;
            if c.add_from.is_some() {
                ms += bytes * 2.0 / 25.0e9 * 1e3 + 0.02;
            }
            if c.gn {
                ms += bytes * 2.0 / 25.0e9 * 1e3 + 0.02;
            }
            if c.barrier_reason == "attention" || c.barrier_reason == "upsample" {
                ms += bytes * 3.0 / 25.0e9 * 1e3 + 0.05;
            }
        }
        return Ok(ms + 0.05);
    }
    let mut ms = 0.0;
    let mut rng = Rng::new(0xf1);
    // classifier head
    if sp.num_classes > 0 {
        if let Some(rel) = man.ew_art(&format!("head_{}", sp.name)) {
            let exec = model.rt.load(&rel)?;
            let last = sp.convs.last().unwrap();
            let x = rand_tensor(&mut rng, &[b, last.h_out(), last.w_out(), sp.head_hidden]);
            let w = rand_tensor(&mut rng, &[sp.head_hidden, sp.num_classes]);
            let bias = rand_tensor(&mut rng, &[sp.num_classes]);
            ms += measure(&exec, &[&x, &w, &bias], cfg.warmup, cfg.iters)?.p50_ms;
        }
    }
    for c in &sp.convs {
        let shape = [b, c.h_out(), c.w_out(), c.cout];
        let base = format!("b{}h{}w{}c{}", b, c.h_out(), c.w_out(), c.cout);
        if c.add_from.is_some() {
            if let Some(rel) = man.ew_art(&format!("add_{base}")) {
                let exec = model.rt.load(&rel)?;
                let x = rand_tensor(&mut rng, &shape);
                let y = rand_tensor(&mut rng, &shape);
                ms += measure(&exec, &[&x, &y], cfg.warmup, cfg.iters)?.p50_ms;
            }
        }
        if c.gn {
            if let Some(rel) = man.ew_art(&format!("gn{}_{base}", c.gn_groups)) {
                let exec = model.rt.load(&rel)?;
                let x = rand_tensor(&mut rng, &shape);
                let s1 = rand_tensor(&mut rng, &[c.cout]);
                let s2 = rand_tensor(&mut rng, &[c.cout]);
                ms += measure(&exec, &[&x, &s1, &s2], cfg.warmup, cfg.iters)?.p50_ms;
            }
        }
        if c.barrier_reason == "attention" {
            if let Some(rel) = man.ew_art(&format!("attn_{base}")) {
                let exec = model.rt.load(&rel)?;
                let x = rand_tensor(&mut rng, &shape);
                let q = rand_tensor(&mut rng, &[c.cout, 3 * c.cout]);
                let o = rand_tensor(&mut rng, &[c.cout, c.cout]);
                ms += measure(&exec, &[&x, &q, &o], cfg.warmup, cfg.iters)?.p50_ms;
            }
        }
        if c.barrier_reason == "upsample" {
            if let Some(rel) = man.ew_art(&format!("up_{base}")) {
                let exec = model.rt.load(&rel)?;
                let x = rand_tensor(&mut rng, &shape);
                ms += measure(&exec, &[&x], cfg.warmup, cfg.iters)?.p50_ms;
            }
        }
    }
    Ok(ms)
}

/// Build (or load from cache) the full table set for a model.
pub fn build(
    model: &Model,
    man: &Manifest,
    gen: &Gen,
    pretrained: &[f32],
    cfg: &BuildCfg,
    cache_root: &Path,
) -> Result<Tables> {
    let fp = fingerprint(pretrained)
        ^ (cfg.proxy_steps as u64) << 32
        ^ cfg.iters as u64;
    let cache = Tables::cache_path(cache_root, &model.name, cfg.mode);
    if !cfg.force {
        if let Some(t) = Tables::load(&cache, fp) {
            eprintln!(
                "[tables] {}: loaded cache ({} entries)",
                model.name,
                t.entries.len()
            );
            return Ok(t);
        }
    }
    let sp = &model.spec;
    let l_max = sp.len();

    // ---- latency ----------------------------------------------------------
    let t0 = Instant::now();
    let mut layer_lat = vec![0.0f64; l_max + 1];
    for c in &sp.convs {
        layer_lat[c.idx] = conv_latency(
            model, man, cfg, sp.batch, c.h_in, c.w_in, c.cin, c.cout, c.k,
            c.stride, c.depthwise, if c.act == "none" { "none" } else { &c.act },
        )?;
    }
    let fixed_ms = fixed_latency(model, man, cfg)?;

    // span entries
    let spans = sp.spans();
    let mut lat_map: BTreeMap<(usize, usize, usize), f64> = BTreeMap::new();
    for &(i, j) in &spans {
        let first = sp.conv(i + 1);
        let act = {
            let cj = sp.conv(j);
            if cj.act == "none" { "relu" } else { cj.act.as_str() }
        };
        for k in sp.kernel_options(i, j) {
            let lat = conv_latency(
                model, man, cfg, sp.batch, first.h_in, first.w_in, first.cin,
                sp.conv(j).cout, k, sp.span_stride(i, j),
                sp.span_depthwise(i, j), act,
            )?;
            lat_map.insert((i, j, k), lat);
        }
    }
    let lat_build_s = t0.elapsed().as_secs_f64();

    // ---- importance (parallel over entries) -------------------------------
    let t1 = Instant::now();
    let (base_loss, base_metric) = crate::train::evaluate(
        model, gen, pretrained, &sp.pristine_gates(), cfg.eval_batches * 2,
    )?;
    let _ = base_loss;
    let base_perf = normalize_perf(sp, base_metric, base_metric) as f64;

    let l1 = csel::layer_l1_norms(sp, pretrained);
    let keys: Vec<(usize, usize, usize)> = lat_map.keys().copied().collect();
    let results: Mutex<BTreeMap<(usize, usize, usize), Entry>> =
        Mutex::new(BTreeMap::new());
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let workers = cfg.workers.max(1).min(keys.len().max(1));
    crate::util::par::par_for_n(keys.len(), workers, |idx| {
        if first_err.lock().unwrap().is_some() {
            return; // an earlier entry failed; drain remaining work fast
        }
        let (i, j, k) = keys[idx];
        let entry = || -> Result<Entry> {
            let kept = csel::select(sp, &l1, i, j, k)
                .with_context(|| format!("csel infeasible ({i},{j},{k})"))?;
            let gates = sp.entry_gates(i, j, &kept);
            let perf = proxy_perf(
                model, gen, pretrained, &gates, cfg.proxy_steps,
                cfg.proxy_lr, cfg.eval_batches,
            )?;
            let perf = normalize_perf(sp, perf, base_metric) as f64;
            let imp = (perf - base_perf).exp();
            // A span whose every conv is dropped deploys as a pure
            // identity — the executor elides it entirely, so its
            // true latency is ~0, not the k=1 conv module's cost.
            let elidable = kept.is_empty()
                && sp.conv(j).add_from.is_none()
                && !sp.conv(j).gn
                && sp.conv(j).barrier_reason.is_empty();
            let lat = if elidable { 0.0 } else { lat_map[&(i, j, k)] };
            Ok(Entry { lat_ms: lat, imp, kept })
        };
        match entry() {
            Ok(e) => {
                results.lock().unwrap().insert((i, j, k), e);
            }
            Err(e) => {
                let mut fe = first_err.lock().unwrap();
                if fe.is_none() {
                    *fe = Some(e);
                }
            }
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    let entries = results.into_inner().unwrap();

    // ---- per-layer keep-importance for LayerOnly ---------------------------
    let mut layer_imp = vec![0.0f64; l_max + 1];
    for c in &sp.convs {
        if !c.conv_gated {
            continue; // forced in the knapsack
        }
        // removing just layer l == entry (l-1, l, 1)
        let key = (c.idx - 1, c.idx, 1usize);
        let perf_without = if let Some(e) = entries.get(&key) {
            base_perf + e.imp.ln()
        } else {
            let gates = sp.entry_gates(c.idx - 1, c.idx, &BTreeSet::new());
            let p = proxy_perf(
                model, gen, pretrained, &gates, cfg.proxy_steps, cfg.proxy_lr,
                cfg.eval_batches,
            )?;
            normalize_perf(sp, p, base_metric) as f64
        };
        layer_imp[c.idx] = (base_perf - perf_without).exp();
    }
    let imp_build_s = t1.elapsed().as_secs_f64();

    let tables = Tables {
        model: model.name.clone(),
        entries,
        layer_lat,
        layer_imp,
        fixed_ms,
        base_perf,
        lat_build_s,
        imp_build_s,
    };
    tables.save(&cache, fp)?;
    eprintln!(
        "[tables] {}: {} entries, lat {:.1}s, imp {:.1}s",
        model.name,
        tables.entries.len(),
        lat_build_s,
        imp_build_s
    );
    Ok(tables)
}

/// The paper's diffusion normalization (App. A): divide negative diffusion
/// loss by the pretrained loss.  Classification metrics pass through.
fn normalize_perf(spec: &Spec, metric: f32, base_metric: f32) -> f32 {
    match spec.task {
        crate::ir::Task::Classify => metric,
        crate::ir::Task::Diffusion => {
            // metric = -loss; base_metric = -loss_pre  =>  -loss/loss_pre
            -(-metric) / (-base_metric).max(1e-8)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytical_latency_grows_with_kernel() {
        let l3 = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let l7 = analytical_conv_ms(32, 16, 16, 64, 64, 7, 1, false);
        let l13 = analytical_conv_ms(32, 16, 16, 64, 64, 13, 1, false);
        assert!(l3 < l7 && l7 < l13, "{l3} {l7} {l13}");
    }

    /// Fig. 1's premise: merging wins where per-dispatch overhead dominates
    /// (small convs), and loses once the merged kernel's k^2 compute
    /// outgrows the saved overhead — the crossover LayerMerge exploits.
    #[test]
    fn analytical_merge_crossover() {
        // tiny conv: overhead-dominated -> merging two 3x3 into one 5x5 wins
        let s3 = analytical_conv_ms(32, 4, 4, 8, 8, 3, 1, false);
        let s5 = analytical_conv_ms(32, 4, 4, 8, 8, 5, 1, false);
        assert!(s5 < 2.0 * s3, "small: {s5} !< {}", 2.0 * s3);
        // big conv: compute-dominated -> the merged kernel loses
        let b3 = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let b5 = analytical_conv_ms(32, 16, 16, 64, 64, 5, 1, false);
        assert!(b5 > 2.0 * b3 * 25.0 / 36.0, "sanity");
        assert!(2.0 * b3 < analytical_conv_ms(32, 16, 16, 64, 64, 13, 1, false));
    }

    #[test]
    fn analytical_depthwise_cheaper() {
        let dense = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let dw = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, true);
        assert!(dw < dense);
    }

    #[test]
    fn fingerprint_sensitive() {
        let a = fingerprint(&[1.0, 2.0, 3.0]);
        let b = fingerprint(&[1.0, 2.0, 3.0001]);
        assert_ne!(a, b);
        assert_eq!(a, fingerprint(&[1.0, 2.0, 3.0]));
    }
}
