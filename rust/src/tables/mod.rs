//! Lookup-table construction — the paper's Sec. 3.2 machinery.
//!
//! * Latency table T[i,j,k]: wall-clock of the merged layer's conv module,
//!   measured through any [`crate::runtime::Backend`] via
//!   [`crate::profile::Profiler`] with the warm-up/average protocol
//!   (App. C), or an analytical roofline model (fast mode / CI).
//! * Importance table I[i,j,k] (Eq. 4): fine-tune the gated network for a
//!   few steps with the (A~_ij, C~_ijk) gate configuration on a proxy data
//!   stream, evaluate, and exponentiate the perf delta ([`build`], which
//!   needs the AOT gated graph); or a deterministic weight-magnitude
//!   proxy for synthetic specs ([`build_host`], no artifacts at all).
//! * Per-layer tables for the LayerOnly baseline (Eq. 8).
//!
//! Construction is embarrassingly parallel (the paper parallelizes across
//! GPUs; we fan out across a thread pool sharing the PJRT client) and the
//! result is cached to JSON keyed by a parameter-vector fingerprint.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::exec::Plan;
use crate::ir::Spec;
use crate::model::Model;
use crate::profile::Profiler;
use crate::runtime::Backend;
use crate::solver::csel;
use crate::solver::dp::SpanArc;
use crate::train::{proxy_perf, Gen};
use crate::util::json::Json;

/// One (i, j, k) table entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub lat_ms: f64,
    pub imp: f64,
    /// \hat{C}_{ijk} — the kept convs realizing kernel size k (Eq. 3).
    pub kept: BTreeSet<usize>,
}

#[derive(Debug, Clone)]
pub struct Tables {
    pub model: String,
    pub entries: BTreeMap<(usize, usize, usize), Entry>,
    /// Per-original-layer latency (1-based; [0] unused).
    pub layer_lat: Vec<f64>,
    /// Keep-importance per layer for LayerOnly (1-based).
    pub layer_imp: Vec<f64>,
    /// Latency of everything outside the merged-conv sum: head, attention,
    /// upsample, norm and unfolded residual adds (sum-approximation, Sec 3.2).
    pub fixed_ms: f64,
    pub base_perf: f64,
    pub lat_build_s: f64,
    pub imp_build_s: f64,
}

impl Tables {
    /// Original-model latency estimate under the same sum approximation.
    pub fn orig_ms(&self) -> f64 {
        self.layer_lat.iter().sum::<f64>() + self.fixed_ms
    }

    /// Arc set for Algorithm 1 (and, restricted, the Depth baseline).
    pub fn arcs(&self, l_max: usize) -> Vec<Vec<SpanArc>> {
        let mut arcs = vec![Vec::new(); l_max + 1];
        for (&(i, j, k), e) in &self.entries {
            arcs[j].push(SpanArc { i, k, lat_ms: e.lat_ms, imp: e.imp });
        }
        arcs
    }

    // ---------------- cache ------------------------------------------------

    pub fn cache_path(root: &Path, model: &str, mode: LatencyMode) -> PathBuf {
        root.join("cache").join(format!("{model}.{}.tables.json", mode.tag()))
    }

    pub fn save(&self, path: &Path, fingerprint: u64) -> Result<()> {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|(&(i, j, k), e)| {
                Json::obj(vec![
                    ("i", Json::num(i as f64)),
                    ("j", Json::num(j as f64)),
                    ("k", Json::num(k as f64)),
                    ("lat", Json::num(e.lat_ms)),
                    ("imp", Json::num(e.imp)),
                    (
                        "kept",
                        Json::Arr(e.kept.iter().map(|&l| Json::num(l as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("fingerprint", Json::num(fingerprint as f64)),
            ("entries", Json::Arr(entries)),
            (
                "layer_lat",
                Json::Arr(self.layer_lat.iter().map(|&v| Json::num(v)).collect()),
            ),
            (
                "layer_imp",
                Json::Arr(self.layer_imp.iter().map(|&v| Json::num(v)).collect()),
            ),
            ("fixed_ms", Json::num(self.fixed_ms)),
            ("base_perf", Json::num(self.base_perf)),
            ("lat_build_s", Json::num(self.lat_build_s)),
            ("imp_build_s", Json::num(self.imp_build_s)),
        ]);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    /// Load a cached table set.  `None` means "rebuild", but the three
    /// causes are no longer conflated: a missing file is the quiet
    /// first-run path, a corrupt file is logged **and deleted** (so the
    /// next build re-measures instead of re-hitting the same bad bytes),
    /// and a fingerprint mismatch (different weights or measurement
    /// protocol) is logged and left in place — it is a valid cache for
    /// whoever built it.
    pub fn load(path: &Path, expect_fingerprint: u64) -> Option<Tables> {
        let text = std::fs::read_to_string(path).ok()?;
        let parsed = Json::parse(&text).ok().and_then(|j| Tables::from_json(&j));
        match parsed {
            None => {
                eprintln!(
                    "[tables] corrupt cache {} — deleting it",
                    path.display()
                );
                let _ = std::fs::remove_file(path);
                None
            }
            Some((_, fp)) if fp != expect_fingerprint => {
                eprintln!(
                    "[tables] cache {} has fingerprint {fp:#x}, want {expect_fingerprint:#x} — rebuilding",
                    path.display()
                );
                None
            }
            Some((t, _)) => Some(t),
        }
    }

    /// Parse the cache JSON; `None` on any structural defect (a missing
    /// or mistyped key means the file is corrupt, not merely stale —
    /// `get`, never the panicking `req`).
    fn from_json(j: &Json) -> Option<(Tables, u64)> {
        let fp = j.get("fingerprint")?.as_f64()? as u64;
        let mut entries = BTreeMap::new();
        for e in j.get("entries")?.as_arr()? {
            let key = (
                e.get("i")?.as_usize()?,
                e.get("j")?.as_usize()?,
                e.get("k")?.as_usize()?,
            );
            entries.insert(
                key,
                Entry {
                    lat_ms: e.get("lat")?.as_f64()?,
                    imp: e.get("imp")?.as_f64()?,
                    kept: e
                        .get("kept")?
                        .as_arr()?
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                },
            );
        }
        Some((
            Tables {
                model: j.get("model")?.as_str()?.to_string(),
                entries,
                layer_lat: j
                    .get("layer_lat")?
                    .as_arr()?
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .collect(),
                layer_imp: j
                    .get("layer_imp")?
                    .as_arr()?
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .collect(),
                fixed_ms: j.get("fixed_ms")?.as_f64()?,
                base_perf: j.get("base_perf")?.as_f64()?,
                lat_build_s: j.get("lat_build_s")?.as_f64()?,
                imp_build_s: j.get("imp_build_s")?.as_f64()?,
            },
            fp,
        ))
    }

    /// Table-predicted latency of a deployed plan, in microseconds (≥ 1)
    /// — the measured seed for a serving rung's cost model.  Each step
    /// takes its (i, j, k) entry's latency, falling back to the sum of
    /// the member layers' solo latencies when that exact entry was never
    /// tabulated (e.g. the original network's singleton spans with k
    /// other than the tabulated options); fixed costs are added once.
    pub fn plan_seed_us(&self, plan: &Plan) -> u64 {
        let mut ms = self.fixed_ms;
        for s in &plan.steps {
            ms += match self.entries.get(&(s.i, s.j, s.merged.k)) {
                Some(e) => e.lat_ms,
                None => (s.i + 1..=s.j)
                    .map(|l| self.layer_lat.get(l).copied().unwrap_or(0.0))
                    .sum(),
            };
        }
        ((ms * 1e3).round() as u64).max(1)
    }
}

/// FNV-1a over the pretrained parameter bytes — cache key.
pub fn fingerprint(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in params {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyMode {
    /// Real wall-clock through PJRT (the paper's protocol).
    Measured,
    /// FLOPs + dispatch-overhead roofline model (fast mode / tests).
    Analytical,
}

impl LatencyMode {
    pub fn tag(&self) -> &'static str {
        match self {
            LatencyMode::Measured => "measured",
            LatencyMode::Analytical => "analytical",
        }
    }
}

/// Builder knobs; the defaults match the scaled-down App. C protocol.
#[derive(Debug, Clone)]
pub struct BuildCfg {
    pub mode: LatencyMode,
    pub warmup: usize,
    pub iters: usize,
    /// Fine-tune steps per importance entry ("a few steps", App. C).
    pub proxy_steps: usize,
    pub proxy_lr: f32,
    pub eval_batches: usize,
    pub workers: usize,
    /// Skip the on-disk cache and rebuild from scratch (`--force`).
    pub force: bool,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg {
            mode: LatencyMode::Measured,
            warmup: 5,
            iters: 30,
            proxy_steps: 8,
            proxy_lr: 0.01,
            eval_batches: 2,
            workers: 1,
            force: false,
        }
    }
}

/// Kernel-configuration fingerprint component: the active SIMD ISA and
/// the backend's weight format both change measured latencies, so cached
/// tables must invalidate when either flips (e.g. `LM_FORCE_SCALAR=1`
/// runs, or `--weight-format int8`).  Mixed with a 64-bit odd constant so
/// the small tag space spreads across the fingerprint domain.
fn kernel_fp(backend: &Arc<dyn Backend>) -> u64 {
    let kfp = (crate::kernels::isa().tag() << 8) | backend.weight_format().tag();
    kfp.wrapping_mul(0x9e37_79b9_97f4_a7c5)
}

/// Analytical per-op latency: max(compute, bandwidth) + dispatch overhead.
/// Calibrated once against CPU-XLA convs; the *shape* (k^2 growth, per-op
/// overhead rewarding depth reduction) is what the solver consumes.
pub fn analytical_conv_ms(
    b: usize,
    h: usize,
    w: usize,
    ci: usize,
    co: usize,
    k: usize,
    s: usize,
    dw: bool,
) -> f64 {
    let (ho, wo) = (h.div_ceil(s), w.div_ceil(s));
    let flops = if dw {
        2.0 * (b * ho * wo * co * k * k) as f64
    } else {
        2.0 * (b * ho * wo * co * ci * k * k) as f64
    };
    let bytes = 4.0 * (b * h * w * ci + b * ho * wo * co + co * ci * k * k) as f64;
    const GFLOPS: f64 = 40.0e9; // effective CPU-XLA conv throughput
    const GBPS: f64 = 25.0e9;
    const DISPATCH_MS: f64 = 0.05;
    (flops / GFLOPS).max(bytes / GBPS) * 1e3 + DISPATCH_MS
}

/// Build (or load from cache) the full table set for a model.
///
/// Latency is measured through `backend` (any [`Backend`] — span/layer
/// signatures are lowered as minimal single-step plans by
/// [`Profiler`]); importance runs the paper's gated-network proxy
/// training, which needs the AOT gated graph and training stream.
pub fn build(
    model: &Model,
    backend: &Arc<dyn Backend>,
    gen: &Gen,
    pretrained: &[f32],
    cfg: &BuildCfg,
    cache_root: &Path,
) -> Result<Tables> {
    let fp = fingerprint(pretrained)
        ^ (cfg.proxy_steps as u64) << 32
        ^ cfg.iters as u64
        ^ kernel_fp(backend);
    let cache = Tables::cache_path(cache_root, &model.name, cfg.mode);
    if !cfg.force {
        if let Some(t) = Tables::load(&cache, fp) {
            eprintln!(
                "[tables] {}: loaded cache ({} entries)",
                model.name,
                t.entries.len()
            );
            return Ok(t);
        }
    }
    let sp = &model.spec;
    let l_max = sp.len();
    let prof = Profiler::from_cfg(Arc::clone(backend), cfg);

    // ---- latency ----------------------------------------------------------
    let t0 = Instant::now();
    let mut layer_lat = vec![0.0f64; l_max + 1];
    for c in &sp.convs {
        layer_lat[c.idx] = prof.layer_ms(sp, c.idx)?;
    }
    let fixed_ms = prof.fixed_ms(sp)?;

    // span entries
    let spans = sp.spans();
    let mut lat_map: BTreeMap<(usize, usize, usize), f64> = BTreeMap::new();
    for &(i, j) in &spans {
        for k in sp.kernel_options(i, j) {
            lat_map.insert((i, j, k), prof.measure_span(sp, i, j, k)?);
        }
    }
    let lat_build_s = t0.elapsed().as_secs_f64();

    // ---- importance (parallel over entries) -------------------------------
    let t1 = Instant::now();
    let (base_loss, base_metric) = crate::train::evaluate(
        model, gen, pretrained, &sp.pristine_gates(), cfg.eval_batches * 2,
    )?;
    let _ = base_loss;
    let base_perf = normalize_perf(sp, base_metric, base_metric) as f64;

    let l1 = csel::layer_l1_norms(sp, pretrained);
    let keys: Vec<(usize, usize, usize)> = lat_map.keys().copied().collect();
    let results: Mutex<BTreeMap<(usize, usize, usize), Entry>> =
        Mutex::new(BTreeMap::new());
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let workers = cfg.workers.max(1).min(keys.len().max(1));
    crate::util::par::par_for_n(keys.len(), workers, |idx| {
        if first_err.lock().unwrap().is_some() {
            return; // an earlier entry failed; drain remaining work fast
        }
        let (i, j, k) = keys[idx];
        let entry = || -> Result<Entry> {
            let kept = csel::select(sp, &l1, i, j, k)
                .with_context(|| format!("csel infeasible ({i},{j},{k})"))?;
            let gates = sp.entry_gates(i, j, &kept);
            let perf = proxy_perf(
                model, gen, pretrained, &gates, cfg.proxy_steps,
                cfg.proxy_lr, cfg.eval_batches,
            )?;
            let perf = normalize_perf(sp, perf, base_metric) as f64;
            let imp = (perf - base_perf).exp();
            // A span whose every conv is dropped deploys as a pure
            // identity — the executor elides it entirely, so its
            // true latency is ~0, not the k=1 conv module's cost.
            let elidable = kept.is_empty()
                && sp.conv(j).add_from.is_none()
                && !sp.conv(j).gn
                && sp.conv(j).barrier_reason.is_empty();
            let lat = if elidable { 0.0 } else { lat_map[&(i, j, k)] };
            Ok(Entry { lat_ms: lat, imp, kept })
        };
        match entry() {
            Ok(e) => {
                results.lock().unwrap().insert((i, j, k), e);
            }
            Err(e) => {
                let mut fe = first_err.lock().unwrap();
                if fe.is_none() {
                    *fe = Some(e);
                }
            }
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    let entries = results.into_inner().unwrap();

    // ---- per-layer keep-importance for LayerOnly ---------------------------
    let mut layer_imp = vec![0.0f64; l_max + 1];
    for c in &sp.convs {
        if !c.conv_gated {
            continue; // forced in the knapsack
        }
        // removing just layer l == entry (l-1, l, 1)
        let key = (c.idx - 1, c.idx, 1usize);
        let perf_without = if let Some(e) = entries.get(&key) {
            base_perf + e.imp.ln()
        } else {
            let gates = sp.entry_gates(c.idx - 1, c.idx, &BTreeSet::new());
            let p = proxy_perf(
                model, gen, pretrained, &gates, cfg.proxy_steps, cfg.proxy_lr,
                cfg.eval_batches,
            )?;
            normalize_perf(sp, p, base_metric) as f64
        };
        layer_imp[c.idx] = (base_perf - perf_without).exp();
    }
    let imp_build_s = t1.elapsed().as_secs_f64();

    let tables = Tables {
        model: model.name.clone(),
        entries,
        layer_lat,
        layer_imp,
        fixed_ms,
        base_perf,
        lat_build_s,
        imp_build_s,
    };
    tables.save(&cache, fp)?;
    eprintln!(
        "[tables] {}: {} entries, lat {:.1}s, imp {:.1}s",
        model.name,
        tables.entries.len(),
        lat_build_s,
        imp_build_s
    );
    Ok(tables)
}

/// Build (or load from cache) tables for a bare `(spec, flat)` pair
/// against any backend — no manifest, no gated graph, no training stream.
///
/// This is the offline paper loop's entry point: latency is genuinely
/// measured (or modeled) through [`Profiler`], while importance uses a
/// deterministic weight-magnitude proxy instead of proxy training —
/// dropping convs costs their share of the network's total conv L1 mass
/// (the same saliency [`csel`] ranks kept sets by):
/// `imp(i,j,k) = exp(-dropped_l1 / total_l1)`, and per-layer
/// keep-importance for LayerOnly is `exp(l1_l / total_l1)`.  The gated
/// proxy-training importance of [`build`] remains the PJRT path.
pub fn build_host(
    spec: &Spec,
    flat: &[f32],
    backend: &Arc<dyn Backend>,
    cfg: &BuildCfg,
    cache_root: &Path,
) -> Result<Tables> {
    // distinct fingerprint domain from `build`: keyed by the measurement
    // protocol (warmup/iters) rather than proxy-training steps
    let fp = fingerprint(flat)
        ^ (cfg.warmup as u64) << 48
        ^ (cfg.iters as u64) << 16
        ^ 0x5eed
        ^ kernel_fp(backend);
    let cache = Tables::cache_path(cache_root, &spec.name, cfg.mode);
    if !cfg.force {
        if let Some(t) = Tables::load(&cache, fp) {
            eprintln!(
                "[tables] {}: loaded cache ({} entries)",
                spec.name,
                t.entries.len()
            );
            return Ok(t);
        }
    }
    let l_max = spec.len();
    let prof = Profiler::from_cfg(Arc::clone(backend), cfg);

    // ---- latency ----------------------------------------------------------
    let t0 = Instant::now();
    let mut layer_lat = vec![0.0f64; l_max + 1];
    for c in &spec.convs {
        layer_lat[c.idx] = prof.layer_ms(spec, c.idx)?;
    }
    let fixed_ms = prof.fixed_ms(spec)?;
    let lat_build_s = t0.elapsed().as_secs_f64();

    // ---- entries (latency measured, importance from L1 mass) --------------
    let t1 = Instant::now();
    let l1 = csel::layer_l1_norms(spec, flat);
    let total_l1: f64 = spec
        .convs
        .iter()
        .filter(|c| c.conv_gated)
        .map(|c| l1[c.idx])
        .sum::<f64>()
        .max(1e-12);
    let mut entries: BTreeMap<(usize, usize, usize), Entry> = BTreeMap::new();
    for &(i, j) in &spec.spans() {
        for k in spec.kernel_options(i, j) {
            let kept = csel::select(spec, &l1, i, j, k)
                .with_context(|| format!("csel infeasible ({i},{j},{k})"))?;
            let dropped: f64 = ((i + 1)..=j)
                .filter(|&l| spec.conv(l).conv_gated && !kept.contains(&l))
                .map(|l| l1[l])
                .sum();
            let imp = (-dropped / total_l1).exp();
            // identical elision rule to `build`: a span whose every conv
            // is dropped deploys as a pure identity
            let elidable = kept.is_empty()
                && spec.conv(j).add_from.is_none()
                && !spec.conv(j).gn
                && spec.conv(j).barrier_reason.is_empty();
            let lat = if elidable {
                0.0
            } else {
                prof.measure_span(spec, i, j, k)?
            };
            entries.insert((i, j, k), Entry { lat_ms: lat, imp, kept });
        }
    }
    let mut layer_imp = vec![0.0f64; l_max + 1];
    for c in &spec.convs {
        if c.conv_gated {
            layer_imp[c.idx] = (l1[c.idx] / total_l1).exp();
        }
    }
    let imp_build_s = t1.elapsed().as_secs_f64();

    let tables = Tables {
        model: spec.name.clone(),
        entries,
        layer_lat,
        layer_imp,
        fixed_ms,
        base_perf: 0.0,
        lat_build_s,
        imp_build_s,
    };
    tables.save(&cache, fp)?;
    eprintln!(
        "[tables] {}: {} entries on {} backend, lat {:.1}s",
        spec.name,
        tables.entries.len(),
        backend.name(),
        lat_build_s
    );
    Ok(tables)
}

/// The paper's diffusion normalization (App. A): divide negative diffusion
/// loss by the pretrained loss.  Classification metrics pass through.
fn normalize_perf(spec: &Spec, metric: f32, base_metric: f32) -> f32 {
    match spec.task {
        crate::ir::Task::Classify => metric,
        crate::ir::Task::Diffusion => {
            // metric = -loss; base_metric = -loss_pre  =>  -loss/loss_pre
            -(-metric) / (-base_metric).max(1e-8)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytical_latency_grows_with_kernel() {
        let l3 = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let l7 = analytical_conv_ms(32, 16, 16, 64, 64, 7, 1, false);
        let l13 = analytical_conv_ms(32, 16, 16, 64, 64, 13, 1, false);
        assert!(l3 < l7 && l7 < l13, "{l3} {l7} {l13}");
    }

    /// Fig. 1's premise: merging wins where per-dispatch overhead dominates
    /// (small convs), and loses once the merged kernel's k^2 compute
    /// outgrows the saved overhead — the crossover LayerMerge exploits.
    #[test]
    fn analytical_merge_crossover() {
        // tiny conv: overhead-dominated -> merging two 3x3 into one 5x5 wins
        let s3 = analytical_conv_ms(32, 4, 4, 8, 8, 3, 1, false);
        let s5 = analytical_conv_ms(32, 4, 4, 8, 8, 5, 1, false);
        assert!(s5 < 2.0 * s3, "small: {s5} !< {}", 2.0 * s3);
        // big conv: compute-dominated -> the merged kernel loses
        let b3 = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let b5 = analytical_conv_ms(32, 16, 16, 64, 64, 5, 1, false);
        assert!(b5 > 2.0 * b3 * 25.0 / 36.0, "sanity");
        assert!(2.0 * b3 < analytical_conv_ms(32, 16, 16, 64, 64, 13, 1, false));
    }

    #[test]
    fn analytical_depthwise_cheaper() {
        let dense = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, false);
        let dw = analytical_conv_ms(32, 16, 16, 64, 64, 3, 1, true);
        assert!(dw < dense);
    }

    #[test]
    fn fingerprint_sensitive() {
        let a = fingerprint(&[1.0, 2.0, 3.0]);
        let b = fingerprint(&[1.0, 2.0, 3.0001]);
        assert_ne!(a, b);
        assert_eq!(a, fingerprint(&[1.0, 2.0, 3.0]));
    }

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("lm_tables_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn tiny_tables() -> Tables {
        let mut entries = BTreeMap::new();
        entries.insert(
            (0, 1, 3),
            Entry { lat_ms: 1.25, imp: 0.9, kept: [1].into_iter().collect() },
        );
        entries.insert(
            (1, 2, 1),
            Entry { lat_ms: 0.5, imp: 0.7, kept: BTreeSet::new() },
        );
        Tables {
            model: "tiny".into(),
            entries,
            layer_lat: vec![0.0, 1.5, 0.75],
            layer_imp: vec![0.0, 1.1, 1.05],
            fixed_ms: 0.25,
            base_perf: 0.5,
            lat_build_s: 0.0,
            imp_build_s: 0.0,
        }
    }

    #[test]
    fn cache_round_trip_preserves_tables() {
        let dir = scratch_dir("roundtrip");
        let t = tiny_tables();
        let path = dir.join("tiny.tables.json");
        t.save(&path, 0xfeed).unwrap();
        let got = Tables::load(&path, 0xfeed).expect("round trip");
        assert_eq!(got.model, t.model);
        assert_eq!(got.entries.len(), t.entries.len());
        let e = &got.entries[&(0, 1, 3)];
        assert!((e.lat_ms - 1.25).abs() < 1e-12 && (e.imp - 0.9).abs() < 1e-12);
        assert_eq!(e.kept, [1].into_iter().collect());
        assert_eq!(got.layer_lat, t.layer_lat);
        assert!((got.fixed_ms - 0.25).abs() < 1e-12);
        assert!((got.orig_ms() - t.orig_ms()).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_keeps_the_file() {
        let dir = scratch_dir("mismatch");
        let path = dir.join("tiny.tables.json");
        tiny_tables().save(&path, 1).unwrap();
        assert!(Tables::load(&path, 2).is_none());
        assert!(path.exists(), "a valid cache for other weights must survive");
        assert!(Tables::load(&path, 1).is_some(), "still loadable by its owner");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_cache_is_deleted() {
        let dir = scratch_dir("corrupt");
        for garbage in ["{not json", r#"{"fingerprint": 3}"#] {
            let path = dir.join("tiny.tables.json");
            std::fs::write(&path, garbage).unwrap();
            assert!(Tables::load(&path, 3).is_none());
            assert!(!path.exists(), "corrupt file must be removed: {garbage}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_cache_is_quietly_none() {
        let dir = scratch_dir("missing");
        let path = dir.join("absent.tables.json");
        assert!(Tables::load(&path, 0).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_seed_us_prefers_entries_and_falls_back() {
        let (spec, flat) = crate::ir::synth::by_name("hostchain-tiny").unwrap();
        let dir = scratch_dir("seed");
        let cfg = BuildCfg {
            mode: LatencyMode::Analytical,
            force: true,
            ..BuildCfg::default()
        };
        let backend: Arc<dyn Backend> = Arc::new(crate::runtime::HostBackend::new());
        let t = build_host(&spec, &flat, &backend, &cfg, &dir).unwrap();
        let mut plan = Plan::original(&spec, &flat).unwrap();
        // every singleton span of the original plan is tabulated
        let expect_ms: f64 = plan
            .steps
            .iter()
            .map(|s| t.entries[&(s.i, s.j, s.merged.k)].lat_ms)
            .sum::<f64>()
            + t.fixed_ms;
        assert_eq!(
            t.plan_seed_us(&plan),
            ((expect_ms * 1e3).round() as u64).max(1)
        );
        // an untabulated kernel size falls back to the member layers' sum
        plan.steps[0].merged.k = 99;
        let fb_ms: f64 = t.layer_lat[1]
            + plan.steps[1..]
                .iter()
                .map(|s| t.entries[&(s.i, s.j, s.merged.k)].lat_ms)
                .sum::<f64>()
            + t.fixed_ms;
        assert_eq!(
            t.plan_seed_us(&plan),
            ((fb_ms * 1e3).round() as u64).max(1)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn build_host_importance_ranks_by_l1_mass() {
        let (spec, flat) = crate::ir::synth::by_name("hostchain-tiny").unwrap();
        let dir = scratch_dir("imp");
        let cfg = BuildCfg {
            mode: LatencyMode::Analytical,
            force: true,
            ..BuildCfg::default()
        };
        let backend: Arc<dyn Backend> = Arc::new(crate::runtime::HostBackend::new());
        let t = build_host(&spec, &flat, &backend, &cfg, &dir).unwrap();
        // keeping everything loses nothing; dropping layers costs mass
        for (&(i, j, _), e) in &t.entries {
            assert!(e.imp > 0.0 && e.imp <= 1.0 + 1e-12, "imp {} at ({i},{j})", e.imp);
        }
        // the full-keep singleton entry has imp exactly 1
        let full = &t.entries[&(1, 2, 3)];
        assert_eq!(full.kept, [2].into_iter().collect());
        assert!((full.imp - 1.0).abs() < 1e-12);
        // gated layers get positive keep-importance for LayerOnly
        for c in &spec.convs {
            if c.conv_gated {
                assert!(t.layer_imp[c.idx] > 1.0, "layer {}", c.idx);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
