//! In-tree substrates replacing crates unavailable in the offline vendor
//! set (DESIGN.md §2): JSON, PRNG, tensors, property testing,
//! pool-backed data parallelism (`par`, the rayon substitute powering
//! the GEMM kernels and table construction), the size-classed scratch
//! recycler (`arena`, the zero-allocation steady-state substrate), and
//! the shared summary statistics (`stats`, the one percentile
//! implementation).

pub mod arena;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensor;
