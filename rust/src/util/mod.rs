//! In-tree substrates replacing crates unavailable in the offline vendor
//! set (DESIGN.md §2): JSON, PRNG, tensors, property testing.

pub mod json;
pub mod prop;
pub mod rng;
pub mod tensor;
