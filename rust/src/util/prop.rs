//! Tiny property-test harness (proptest substitute — offline vendor set).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it reports the failing seed so the case
//! replays deterministically, and greedily re-runs nearby seeds to surface
//! the smallest failing draw the generator can express.

use super::rng::Rng;

/// Run `prop` on `cases` generated inputs; panics with the failing seed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5eed ^ seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at seed {seed}:\n  input = {input:?}"
            );
        }
    }
}

/// Like `check` but the property returns a Result carrying a reason.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x5eed ^ seed);
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property '{name}' failed at seed {seed}: {why}\n  input = {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("add commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn reports_failure() {
        check("always false", 5, |r| r.below(10), |_| false);
    }
}
