//! Data-parallel helpers over a **persistent compute pool** (rayon
//! substitute — offline vendor set, DESIGN.md §2).  Three primitives cover
//! every hot loop in the repo: disjoint-chunk iteration over a mutable
//! slice (GEMM rows, kernel scatter), a work-stealing indexed for-loop
//! (table construction), and a persistent named [`Pool`] of owned worker
//! threads (the serving queue).
//!
//! Historically the chunk/for-n helpers spawned a fresh
//! `std::thread::scope` per call (~10µs/thread), which dominated the
//! steady-state host forward: dozens of GEMM/conv/epilogue dispatches per
//! forward each paid the spawn tax.  They now inject tasks into a
//! lazily-initialized global [`ComputePool`] of parked workers: dispatch
//! is one mutex push + condvar notify, chunks are claimed with an atomic
//! counter (uneven per-chunk cost still self-balances), and the
//! submitting thread participates, so correctness never depends on any
//! worker existing.  `pool_spawns()` is monotonic — tests pin
//! zero-thread-spawn steady state with it.  The legacy scoped-spawn path
//! is kept as [`par_chunks_mut_scoped`], the baseline side of the
//! `benches/merge_ops.rs` pool-dispatch comparison.
//!
//! Tasks that run *inside* a pool job observe [`in_pool_worker`] and
//! execute nested `par_*` calls serially — nested parallelism (e.g. the
//! per-batch GEMMs inside a batch-parallel attention) degrades to clean
//! sequential code instead of thrashing the queue.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Hardware parallelism, clamped by the `LM_THREADS` env override.
pub fn max_threads() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("LM_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n.min(hw.max(1) * 4),
        _ => hw,
    }
}

/// Thread budget for a data-parallel pass over `len` elements: serial
/// below a quarter-MiB of f32s (task injection is cheap but not free, and
/// small loops finish before a parked worker wakes), otherwise
/// [`max_threads`].  The single knob shared by the elementwise host
/// kernels and the exec glue loops.
pub fn auto_threads(len: usize) -> usize {
    if len < (1 << 18) {
        1
    } else {
        max_threads()
    }
}

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// True while the current thread is executing a task claimed from the
/// global compute pool (worker threads *and* participating submitters).
/// `par_chunks_mut` / `par_for_n` check this and run serially — nested
/// data parallelism inside a pool task degrades to sequential execution.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// One injected parallel job: `n` tasks claimed by atomic counter.  The
/// task reference is transmuted to `'static` at dispatch; safety rests on
/// `dispatch` not returning until `pending` reaches zero (every claimed
/// task has finished) and on removing the job from the queue before
/// returning (no stale reference survives the call).
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    n: usize,
    /// next task index to claim (claims past `n` are no-ops)
    next: AtomicUsize,
    /// tasks not yet completed; the submitter blocks until 0
    pending: AtomicUsize,
    /// a claimed task panicked — the submitter re-raises after the join
    poisoned: AtomicBool,
    payload: Mutex<Option<Box<dyn Any + Send>>>,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

struct PoolState {
    queue: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct ComputePool {
    inner: Arc<PoolInner>,
    handles: Vec<JoinHandle<()>>,
}

static POOL: Mutex<Option<ComputePool>> = Mutex::new(None);
/// Monotonic count of compute-pool threads ever spawned (the
/// zero-spawn-steady-state assertion reads it).
static SPAWNED: AtomicUsize = AtomicUsize::new(0);

fn pool() -> Arc<PoolInner> {
    let mut g = POOL.lock().unwrap();
    if g.is_none() {
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        // the submitter participates in every job, so N-1 workers give N-way
        // parallelism; a 1-thread budget runs everything on the submitter
        let workers = max_threads().saturating_sub(1);
        let handles = (0..workers)
            .map(|i| {
                SPAWNED.fetch_add(1, Ordering::Relaxed);
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("lm-compute-{i}"))
                    .spawn(move || worker(&inner))
                    .expect("spawn compute-pool worker")
            })
            .collect();
        *g = Some(ComputePool { inner, handles });
    }
    Arc::clone(&g.as_ref().unwrap().inner)
}

/// Live compute-pool worker threads (0 before first dispatch / after
/// [`shutdown_pool`]).
pub fn pool_threads() -> usize {
    POOL.lock().unwrap().as_ref().map_or(0, |p| p.handles.len())
}

/// Total compute-pool threads ever spawned (monotonic).  Steady-state
/// forwards must leave this unchanged — pinned by `tests/steady_state.rs`.
pub fn pool_spawns() -> usize {
    SPAWNED.load(Ordering::Relaxed)
}

/// Live long-lived pool users ([`crate::serve::Session`]s and fleets) —
/// [`shutdown_pool`] refuses to run while any exist.
static SERVING: AtomicUsize = AtomicUsize::new(0);

/// RAII mark of a long-lived compute-pool user.  A serving engine holds
/// one for its whole lifetime so [`shutdown_pool`] fails loudly instead
/// of silently degrading every in-flight batch of a live session to
/// single-threaded self-service.
#[derive(Debug)]
pub struct ServingGuard(());

impl Drop for ServingGuard {
    fn drop(&mut self) {
        SERVING.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Mark the caller as a long-lived pool user until the guard drops.
pub fn serving_guard() -> ServingGuard {
    SERVING.fetch_add(1, Ordering::AcqRel);
    ServingGuard(())
}

/// Live long-lived pool users (sessions + fleets currently up).
pub fn serving_users() -> usize {
    SERVING.load(Ordering::Acquire)
}

/// Tear the global pool down: signal, join, forget.  In-flight jobs
/// complete first (workers drain the queue before exiting; submitters
/// always self-serve).  The next `par_*` call lazily re-creates the pool.
///
/// # Panics
/// While a serving engine (a [`crate::serve::Session`] or fleet holding a
/// [`ServingGuard`]) is live — tearing the pool out from under one is a
/// lifecycle bug, and a loud panic beats a silent throughput collapse.
pub fn shutdown_pool() {
    let users = serving_users();
    assert!(
        users == 0,
        "par::shutdown_pool() with {users} live serving engine(s): \
         close/drop every serve::Session and serve::Fleet first"
    );
    force_shutdown_pool();
}

/// [`shutdown_pool`] that declines (returns `false`) instead of panicking
/// when a serving engine is live — for callers racing against engines
/// they do not own (e.g. concurrently-running tests).
pub fn try_shutdown_pool() -> bool {
    if serving_users() > 0 {
        return false;
    }
    force_shutdown_pool();
    true
}

fn force_shutdown_pool() {
    let taken = POOL.lock().unwrap().take();
    if let Some(mut p) = taken {
        p.inner.state.lock().unwrap().shutdown = true;
        p.inner.work_cv.notify_all();
        for h in p.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker(inner: &PoolInner) {
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                // drop exhausted jobs off the front (all tasks claimed)
                while st
                    .queue
                    .front()
                    .is_some_and(|j| j.next.load(Ordering::Relaxed) >= j.n)
                {
                    st.queue.pop_front();
                }
                if let Some(j) = st.queue.front() {
                    break Arc::clone(j);
                }
                if st.shutdown {
                    return;
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        run_chunks(&job);
    }
}

/// Claim-and-run tasks from `job` until none remain.  Panics inside a
/// task are captured (first payload wins) so `pending` always drains —
/// a dead worker or an unwound submitter must never strand the job.
fn run_chunks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            return;
        }
        let prev = IN_POOL_WORKER.with(|c| c.replace(true));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.task)(i)));
        IN_POOL_WORKER.with(|c| c.set(prev));
        if let Err(p) = r {
            job.poisoned.store(true, Ordering::Release);
            let mut slot = job.payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = job.done_mx.lock().unwrap();
            job.done_cv.notify_all();
        }
    }
}

/// Inject `n` tasks into the global pool and run `f(i)` once for each
/// `i in 0..n`, participating from the calling thread.  Returns once all
/// tasks completed; re-raises the first captured task panic.
fn dispatch(n: usize, f: &(dyn Fn(usize) + Sync)) {
    debug_assert!(n > 0);
    // SAFETY: the job's task reference never outlives this call — we do
    // not return until `pending == 0` (every claimed task finished) and
    // the job has been removed from the queue; workers dereference `task`
    // only for claims `< n`, each of which completes before its matching
    // `pending` decrement.
    let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    let job = Arc::new(Job {
        task,
        n,
        next: AtomicUsize::new(0),
        pending: AtomicUsize::new(n),
        poisoned: AtomicBool::new(false),
        payload: Mutex::new(None),
        done_mx: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    let inner = pool();
    inner.state.lock().unwrap().queue.push_back(Arc::clone(&job));
    inner.work_cv.notify_all();
    run_chunks(&job);
    {
        let mut g = job.done_mx.lock().unwrap();
        while job.pending.load(Ordering::Acquire) > 0 {
            g = job.done_cv.wait(g).unwrap();
        }
    }
    // unlink the job so no queue entry outlives the task borrow
    {
        let mut st = inner.state.lock().unwrap();
        if let Some(pos) = st.queue.iter().position(|j| Arc::ptr_eq(j, &job)) {
            let _ = st.queue.remove(pos);
        }
    }
    if job.poisoned.load(Ordering::Acquire) {
        match job.payload.lock().unwrap().take() {
            Some(p) => std::panic::resume_unwind(p),
            None => panic!("compute-pool task panicked"),
        }
    }
}

/// Run `f(chunk_index, chunk)` over `chunk_len`-sized disjoint chunks of
/// `data`, distributing chunks across the compute pool when `threads > 1`.
/// Chunks are claimed atomically, so uneven per-chunk cost balances
/// itself.  Inside a pool task (see [`in_pool_worker`]) this runs
/// serially.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 || in_pool_worker() {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let len = data.len();
    let base = data.as_mut_ptr() as usize;
    let task = move |i: usize| {
        let start = i * chunk_len;
        let end = (start + chunk_len).min(len);
        // SAFETY: each chunk index is claimed exactly once (atomic
        // fetch_add in the pool), so these slices are disjoint; `data` is
        // not touched again until `dispatch` returns.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start) };
        f(i, chunk);
    };
    dispatch(n_chunks, &task);
}

/// Work-stealing parallel for over `0..n` on the compute pool.
/// `f` must be safe to call concurrently from multiple threads.
pub fn par_for_n<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 || in_pool_worker() {
        for i in 0..n {
            f(i);
        }
        return;
    }
    dispatch(n, &f);
}

/// The legacy per-call `std::thread::scope` chunk loop — **baseline
/// only**: `benches/merge_ops.rs` compares pool dispatch against it.
/// Production callers use [`par_chunks_mut`].
pub fn par_chunks_mut_scoped<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len).max(1);
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 || data.is_empty() {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let slots: Vec<Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|c| Mutex::new(Some(c)))
        .collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    return;
                }
                if let Some((idx, chunk)) = slots[i].lock().unwrap().take() {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// A persistent pool of owned, named worker threads.
///
/// Unlike the chunk/for-n helpers above (which inject short tasks into the
/// shared compute pool), `Pool` threads are `'static` and run one
/// long-lived body each: the worker body owns everything it touches
/// (typically `Arc`-shared state), so the pool can be stored in a
/// long-lived handle such as [`crate::serve::Session`].  Workers run
/// `f(worker_index)` once and exit when `f` returns; coordination
/// (queues, shutdown flags) lives in the shared state, not in the pool.
pub struct Pool {
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `threads` workers (at least one) named `"{name}-{i}"`, each
    /// running `f(i)` to completion.
    pub fn spawn<F>(threads: usize, name: &str, f: F) -> Pool
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles = (0..threads.max(1))
            .map(|i| {
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || f(i))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Join every worker.  Idempotent; callers must first arrange for the
    /// worker bodies to return (e.g. close their queue) or this blocks.
    pub fn join(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_elements() {
        let mut v: Vec<u32> = vec![0; 1003];
        par_chunks_mut(&mut v, 64, 4, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 64 + off) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn chunks_serial_fallback() {
        let mut v = vec![1u8; 10];
        par_chunks_mut(&mut v, 4, 1, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn scoped_baseline_matches_pool_path() {
        let mut a: Vec<u32> = vec![0; 517];
        let mut b: Vec<u32> = vec![0; 517];
        par_chunks_mut(&mut a, 32, 4, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 1000 + off) as u32;
            }
        });
        par_chunks_mut_scoped(&mut b, 32, 4, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 1000 + off) as u32;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn for_n_visits_each_index_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        par_for_n(100, 8, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn for_n_empty_and_tiny() {
        par_for_n(0, 4, |_| panic!("must not run"));
        let hits = AtomicU64::new(0);
        par_for_n(1, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_parallel_calls_run_serially_and_correctly() {
        // a parallel task that itself calls par_for_n: the inner call must
        // observe in_pool_worker() and degrade to serial — no deadlock,
        // same results
        let hits = AtomicU64::new(0);
        par_for_n(8, 4, |_| {
            par_for_n(16, 4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn task_panic_propagates_to_the_submitter() {
        let r = std::panic::catch_unwind(|| {
            par_for_n(8, 4, |i| {
                if i == 5 {
                    panic!("boom from task 5");
                }
            });
        });
        let p = r.expect_err("panic must propagate");
        let msg = p
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("boom"), "payload preserved, got {msg:?}");
    }

    #[test]
    fn pool_self_serves_after_shutdown() {
        // shutting the global pool down must not break correctness: a
        // dispatch against a shut (or re-created) pool still completes —
        // the submitter claims every task itself if no worker exists
        // (try_: a concurrent test may hold a live session; declining is
        // fine — the dispatch below works either way)
        let hits = AtomicU64::new(0);
        par_for_n(32, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        let _ = try_shutdown_pool();
        par_for_n(32, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn shutdown_refuses_while_a_serving_guard_is_live() {
        let g = serving_guard();
        assert_eq!(serving_users(), 1);
        let r = std::panic::catch_unwind(shutdown_pool);
        assert!(r.is_err(), "shutdown_pool must panic under a live guard");
        drop(g);
        assert_eq!(serving_users(), 0);
    }

    #[test]
    fn max_threads_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn auto_threads_serial_below_threshold() {
        assert_eq!(auto_threads(0), 1);
        assert_eq!(auto_threads((1 << 18) - 1), 1);
        assert!(auto_threads(1 << 18) >= 1);
    }

    #[test]
    fn pool_runs_each_worker_once_and_joins() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = Arc::clone(&hits);
        let mut pool = Pool::spawn(3, "test-pool", move |i| {
            h2.fetch_add(1 + i as u64, Ordering::Relaxed);
        });
        assert_eq!(pool.len(), 3);
        pool.join();
        // 0-, 1- and 2-indexed workers each ran once: 1 + 2 + 3
        assert_eq!(hits.load(Ordering::Relaxed), 6);
        pool.join(); // idempotent
    }

    #[test]
    fn pool_spawns_at_least_one_worker() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = Arc::clone(&hits);
        let mut pool = Pool::spawn(0, "test-pool-min", move |_| {
            h2.fetch_add(1, Ordering::Relaxed);
        });
        pool.join();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
