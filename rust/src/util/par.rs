//! Scoped-thread data-parallel helpers (rayon substitute — offline vendor
//! set, DESIGN.md §2).  Three primitives cover every hot loop in the repo:
//! disjoint-chunk iteration over a mutable slice (GEMM rows, kernel
//! scatter), a work-stealing indexed for-loop (table construction), and a
//! persistent named [`Pool`] of owned worker threads (the serving queue).
//!
//! The scoped helpers spawn per call via `std::thread::scope`; spawn cost
//! is ~10µs/thread, so callers gate on problem size (see
//! [`crate::kernels::gemm`]) and stay serial below it.  `Pool` threads are
//! long-lived and joined explicitly (or on drop).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Hardware parallelism, clamped by the `LM_THREADS` env override.
pub fn max_threads() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("LM_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n.min(hw.max(1) * 4),
        _ => hw,
    }
}

/// Thread budget for a data-parallel pass over `len` elements: serial
/// below a quarter-MiB of f32s (scoped-thread spawn is ~10µs each, which
/// would dominate), otherwise [`max_threads`].  The single knob shared by
/// the elementwise host kernels and the exec glue loops.
pub fn auto_threads(len: usize) -> usize {
    if len < (1 << 18) {
        1
    } else {
        max_threads()
    }
}

/// Run `f(chunk_index, chunk)` over `chunk_len`-sized disjoint chunks of
/// `data`, distributing chunks across up to `threads` workers.  Chunks are
/// claimed atomically, so uneven per-chunk cost balances itself.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len.max(1)).max(1);
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 || data.is_empty() {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|c| std::sync::Mutex::new(Some(c)))
        .collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    return;
                }
                if let Some((idx, chunk)) = slots[i].lock().unwrap().take() {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// Work-stealing parallel for over `0..n` with up to `threads` workers.
/// `f` must be safe to call concurrently from multiple threads.
pub fn par_for_n<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

/// A persistent pool of owned, named worker threads.
///
/// Unlike the scoped helpers above, `Pool` threads are `'static`: the
/// worker body owns everything it touches (typically `Arc`-shared state),
/// so the pool can be stored in a long-lived handle such as
/// [`crate::serve::Session`].  Workers run `f(worker_index)` once and exit
/// when `f` returns; coordination (queues, shutdown flags) lives in the
/// shared state, not in the pool.
pub struct Pool {
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `threads` workers (at least one) named `"{name}-{i}"`, each
    /// running `f(i)` to completion.
    pub fn spawn<F>(threads: usize, name: &str, f: F) -> Pool
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles = (0..threads.max(1))
            .map(|i| {
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || f(i))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Join every worker.  Idempotent; callers must first arrange for the
    /// worker bodies to return (e.g. close their queue) or this blocks.
    pub fn join(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_elements() {
        let mut v: Vec<u32> = vec![0; 1003];
        par_chunks_mut(&mut v, 64, 4, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 64 + off) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn chunks_serial_fallback() {
        let mut v = vec![1u8; 10];
        par_chunks_mut(&mut v, 4, 1, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn for_n_visits_each_index_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        par_for_n(100, 8, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn for_n_empty_and_tiny() {
        par_for_n(0, 4, |_| panic!("must not run"));
        let hits = AtomicU64::new(0);
        par_for_n(1, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn max_threads_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn auto_threads_serial_below_threshold() {
        assert_eq!(auto_threads(0), 1);
        assert_eq!(auto_threads((1 << 18) - 1), 1);
        assert!(auto_threads(1 << 18) >= 1);
    }

    #[test]
    fn pool_runs_each_worker_once_and_joins() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = Arc::clone(&hits);
        let mut pool = Pool::spawn(3, "test-pool", move |i| {
            h2.fetch_add(1 + i as u64, Ordering::Relaxed);
        });
        assert_eq!(pool.len(), 3);
        pool.join();
        // 0-, 1- and 2-indexed workers each ran once: 1 + 2 + 3
        assert_eq!(hits.load(Ordering::Relaxed), 6);
        pool.join(); // idempotent
    }

    #[test]
    fn pool_spawns_at_least_one_worker() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = Arc::clone(&hits);
        let mut pool = Pool::spawn(0, "test-pool-min", move |_| {
            h2.fetch_add(1, Ordering::Relaxed);
        });
        pool.join();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
