//! Scoped-thread data-parallel helpers (rayon substitute — offline vendor
//! set, DESIGN.md §2).  Two primitives cover every hot loop in the repo:
//! disjoint-chunk iteration over a mutable slice (GEMM rows, kernel
//! scatter) and a work-stealing indexed for-loop (table construction).
//!
//! Threads are spawned per call via `std::thread::scope`; spawn cost is
//! ~10µs/thread, so callers gate on problem size (see
//! [`crate::kernels::gemm`]) and stay serial below it.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Hardware parallelism, clamped by the `LM_THREADS` env override.
pub fn max_threads() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("LM_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n.min(hw.max(1) * 4),
        _ => hw,
    }
}

/// Run `f(chunk_index, chunk)` over `chunk_len`-sized disjoint chunks of
/// `data`, distributing chunks across up to `threads` workers.  Chunks are
/// claimed atomically, so uneven per-chunk cost balances itself.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len.max(1)).max(1);
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 || data.is_empty() {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|c| std::sync::Mutex::new(Some(c)))
        .collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    return;
                }
                if let Some((idx, chunk)) = slots[i].lock().unwrap().take() {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// Work-stealing parallel for over `0..n` with up to `threads` workers.
/// `f` must be safe to call concurrently from multiple threads.
pub fn par_for_n<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_elements() {
        let mut v: Vec<u32> = vec![0; 1003];
        par_chunks_mut(&mut v, 64, 4, |idx, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 64 + off) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn chunks_serial_fallback() {
        let mut v = vec![1u8; 10];
        par_chunks_mut(&mut v, 4, 1, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn for_n_visits_each_index_once() {
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        par_for_n(100, 8, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn for_n_empty_and_tiny() {
        par_for_n(0, 4, |_| panic!("must not run"));
        let hits = AtomicU64::new(0);
        par_for_n(1, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn max_threads_positive() {
        assert!(max_threads() >= 1);
    }
}
