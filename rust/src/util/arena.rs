//! Size-classed scratch arena — the allocation recycler behind the
//! zero-allocation steady-state host forward.
//!
//! Every transient f32 buffer on the host execution path (im2col columns,
//! pad buffers, attention scratch, inter-step activation tensors, the
//! uploaded input) is taken from an [`Arena`] and given back when its last
//! reference drops (see `runtime::backend::Value`).  Buffers are keyed by
//! exact length — a lowered plan requests the same shapes every forward,
//! so from the second forward on every `take` is a **hit** and the forward
//! performs no buffer allocation at all.  `hits()` / `misses()` are
//! monotonic counters; `tests/steady_state.rs` pins "misses stop growing
//! after the first forward".
//!
//! Freelists are sharded by thread (first-touch assignment), which is what
//! makes the arena per-worker in `serve`: each serving worker takes and
//! returns its buffers on its own shard, so concurrent sessions never
//! contend and every worker reaches its own zero-alloc steady state after
//! one warm forward (see `ServeCfg::warmup`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Shard count — an upper bound on useful take/give concurrency, not on
/// correctness (threads hashing to the same shard just share a freelist).
const SHARDS: usize = 8;

/// Buffers retained per (shard, length) class; beyond this, `give` frees
/// instead of caching so a pathological caller can't grow the arena
/// without bound.
const MAX_PER_CLASS: usize = 32;

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);
thread_local! {
    /// First-touch shard assignment: stable for the thread's lifetime.
    static SHARD_IDX: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed);
}

pub struct Arena {
    shards: Vec<Mutex<HashMap<usize, Vec<Vec<f32>>>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for Arena {
    fn default() -> Self {
        Arena::new()
    }
}

impl Arena {
    pub fn new() -> Arena {
        Arena {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    fn shard(&self) -> &Mutex<HashMap<usize, Vec<Vec<f32>>>> {
        let idx = SHARD_IDX.with(|i| *i) % SHARDS;
        &self.shards[idx]
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (callers that fully overwrite it — im2col gathers, elementwise
    /// outputs — skip the zeroing pass).  Zero-length requests are free
    /// and uncounted.
    pub fn take(&self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let recycled = self.shard().lock().unwrap().get_mut(&len).and_then(Vec::pop);
        match recycled {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                debug_assert_eq!(v.len(), len);
                v
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// [`Arena::take`], but guaranteed zero-filled (GEMM accumulators,
    /// padded planes).
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let recycled = self.shard().lock().unwrap().get_mut(&len).and_then(Vec::pop);
        match recycled {
            Some(mut v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                debug_assert_eq!(v.len(), len);
                v.fill(0.0);
                v
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse.  Any `Vec<f32>` is adopted (buffers that
    /// were allocated outside the arena seed the freelist); empty vectors
    /// are ignored.
    pub fn give(&self, v: Vec<f32>) {
        if v.is_empty() {
            return;
        }
        let mut shard = self.shard().lock().unwrap();
        let class = shard.entry(v.len()).or_default();
        if class.len() < MAX_PER_CLASS {
            class.push(v);
        }
    }

    /// Takes served from a recycled buffer (monotonic).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Takes that had to allocate (monotonic).  Flat across steady-state
    /// forwards — the zero-allocation assertion.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers currently cached across all shards (diagnostics).
    pub fn cached(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().map(Vec::len).sum::<usize>())
            .sum()
    }

    /// Drop every cached buffer (counters are preserved).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_take_is_a_hit() {
        let a = Arena::new();
        let v = a.take(128);
        assert_eq!((a.hits(), a.misses()), (0, 1));
        a.give(v);
        let v2 = a.take(128);
        assert_eq!((a.hits(), a.misses()), (1, 1));
        assert_eq!(v2.len(), 128);
        // a different size misses again
        let _ = a.take(64);
        assert_eq!(a.misses(), 2);
    }

    #[test]
    fn take_zeroed_scrubs_recycled_contents() {
        let a = Arena::new();
        let mut v = a.take(16);
        v.fill(7.5);
        a.give(v);
        let v2 = a.take_zeroed(16);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!((a.hits(), a.misses()), (1, 1));
    }

    #[test]
    fn zero_length_is_free_and_uncounted() {
        let a = Arena::new();
        assert!(a.take(0).is_empty());
        a.give(Vec::new());
        assert_eq!((a.hits(), a.misses()), (0, 0));
        assert_eq!(a.cached(), 0);
    }

    #[test]
    fn class_retention_is_bounded() {
        let a = Arena::new();
        for _ in 0..(MAX_PER_CLASS + 10) {
            a.give(vec![0.0; 8]);
        }
        assert_eq!(a.cached(), MAX_PER_CLASS);
        a.clear();
        assert_eq!(a.cached(), 0);
    }

    #[test]
    fn adopts_foreign_buffers() {
        let a = Arena::new();
        a.give(vec![1.0; 32]); // not arena-born — seeds the freelist
        let v = a.take(32);
        assert_eq!((a.hits(), a.misses()), (1, 0));
        assert_eq!(v.len(), 32);
    }
}
