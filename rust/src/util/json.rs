//! Minimal JSON parser/emitter (serde_json substitute — the offline vendor
//! set has no serde; see DESIGN.md §2 "Offline-toolchain substitutions").
//!
//! Supports the full JSON grammar we exchange with the Python compile path:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key: {key}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- emitter -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out);
        out
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).emit(out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_bool(),
            Some(false)
        );
        assert_eq!(v.req("c").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"convs":[{"idx":1,"k":3,"dw":false}],"name":"m","x":1.5}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn emits_ints_cleanly() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
