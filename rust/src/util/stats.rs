//! Shared summary statistics — the crate's **single** percentile
//! implementation.
//!
//! Every latency quantile in the repo (the App. C measurement protocol,
//! the serving load reports, the bench harness) goes through
//! [`percentile`], so numbers are comparable across subsystems and the
//! old off-by-one index math (`times[(n as f64 * 0.95) as usize]`, which
//! returns the *maximum* for n <= 20, and the upper-biased `times[n/2]`
//! median) cannot recur.

/// Nearest-rank percentile of an ascending-sorted, non-empty slice.
///
/// Returns the smallest element `x` such that at least `q * 100` percent
/// of the samples are `<= x` (the classic nearest-rank definition:
/// `rank = ceil(q * n)`, 1-based).  `q` is clamped to `[0, 1]`; `q = 0`
/// yields the minimum and `q = 1` the maximum.  In particular, for
/// `n = 20` the p95 is the 19th value, **not** the maximum, and the p50
/// is the lower-middle value, not the upper one.
///
/// Panics on an empty slice (there is no percentile of nothing); callers
/// guard with their own "no samples" error first.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile input must be sorted ascending"
    );
    let n = sorted.len();
    let q = q.clamp(0.0, 1.0);
    // The epsilon guards binary-representation noise: 0.95f64 * 20.0 is
    // 19.000000000000004, whose ceil would land on the maximum again.
    let rank = (q * n as f64 - 1e-9).ceil().max(1.0) as usize;
    sorted[rank.min(n) - 1]
}

/// Sort a sample vector ascending (total order on finite floats) — the
/// preparation step every percentile caller shares.
pub fn sort_samples(samples: &mut [f64]) {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("non-finite sample"));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize) -> Vec<f64> {
        (1..=n).map(|i| i as f64).collect()
    }

    #[test]
    fn n1_every_quantile_is_the_sample() {
        let xs = seq(1);
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(percentile(&xs, q), 1.0);
        }
    }

    #[test]
    fn n2_median_is_lower_p95_is_upper() {
        let xs = seq(2);
        assert_eq!(percentile(&xs, 0.5), 1.0);
        assert_eq!(percentile(&xs, 0.95), 2.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 2.0);
    }

    #[test]
    fn n20_p95_is_the_19th_value_not_the_max() {
        // the regression this helper exists for: the old index math
        // ((20 as f64 * 0.95) as usize) = 19 returned xs[19] = the max
        let xs = seq(20);
        assert_eq!(percentile(&xs, 0.95), 19.0);
        assert_eq!(percentile(&xs, 0.5), 10.0);
        assert_eq!(percentile(&xs, 1.0), 20.0);
    }

    #[test]
    fn n100_nearest_rank_matches_hand_count() {
        let xs = seq(100);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.01), 1.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
    }

    #[test]
    fn q_is_clamped() {
        let xs = seq(5);
        assert_eq!(percentile(&xs, -3.0), 1.0);
        assert_eq!(percentile(&xs, 7.0), 5.0);
    }

    #[test]
    fn sort_samples_orders_ascending() {
        let mut v = vec![3.0, 1.0, 2.0];
        sort_samples(&mut v);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "empty sample set")]
    fn empty_panics() {
        percentile(&[], 0.5);
    }
}
