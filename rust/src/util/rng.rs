//! Deterministic PRNG (SplitMix64 core) — the offline vendor set has no
//! `rand`; this powers synthetic data generation, property tests, and any
//! stochastic scheduling.  Everything in the repo is seeded, so experiment
//! rows in EXPERIMENTS.md are exactly reproducible.

/// SplitMix64: tiny, fast, passes BigCrush on its 64-bit outputs.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fork a stream (stable across call order at the fork site).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Seed from an environment variable (decimal or `0x` hex), falling
    /// back to `default` when unset or unparsable.  Chaos runs pin their
    /// fault schedule with `LM_CHAOS_SEED` through this.
    pub fn from_env(var: &str, default: u64) -> Rng {
        Rng::new(seed_from_env(var, default))
    }
}

/// Parse a seed from `var` (decimal or `0x`-prefixed hex); `default`
/// when unset or malformed.
pub fn seed_from_env(var: &str, default: u64) -> u64 {
    match std::env::var(var) {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse::<u64>(),
            };
            parsed.unwrap_or(default)
        }
        Err(_) => default,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn env_seed_parses_decimal_and_hex() {
        // no env mutation in tests (parallel test runner): exercise the
        // parser through a variable that cannot exist
        assert_eq!(seed_from_env("LM_SEED_THAT_IS_NEVER_SET_7QX", 9), 9);
        let mut a = Rng::from_env("LM_SEED_THAT_IS_NEVER_SET_7QX", 42);
        let mut b = Rng::new(42);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
