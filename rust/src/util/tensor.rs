//! Host-side dense f32 tensor — the lingua franca between the coordinator,
//! the PJRT runtime and the merge algebra.  Deliberately simple: row-major,
//! f32 only (everything this system exchanges with the AOT artifacts is f32).

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.dims, self.data.len())
    }
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "dims {dims:?} vs len {}", data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(dims: &[usize], v: f32) -> Self {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major linear index for a 4-d tensor.
    #[inline]
    pub fn idx4(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert_eq!(self.dims.len(), 4);
        ((a * self.dims[1] + b) * self.dims[2] + c) * self.dims[3] + d
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        self.data[self.idx4(a, b, c, d)]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let i = self.idx4(a, b, c, d);
        self.data[i] = v;
    }

    pub fn reshape(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 distance ||a-b|| / (||b|| + eps).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = other.data.iter().map(|b| b * b).sum();
        (num.sqrt()) / (den.sqrt() + 1e-12)
    }

    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    // ---- binary IO (matches the <f4 layout of artifacts/<m>/init.bin) -----

    pub fn read_f32_file(path: &std::path::Path) -> std::io::Result<Vec<f32>> {
        let bytes = std::fs::read(path)?;
        assert_eq!(bytes.len() % 4, 0, "{path:?} not f32-aligned");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn write_f32_file(path: &std::path::Path, data: &[f32]) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7.0);
        assert_eq!(t.at4(1, 2, 3, 4), 7.0);
        assert_eq!(t.data.iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("lm_tensor_test");
        let path = dir.join("t.bin");
        let data = vec![1.0f32, -2.5, 3.25];
        Tensor::write_f32_file(&path, &data).unwrap();
        assert_eq!(Tensor::read_f32_file(&path).unwrap(), data);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 4.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert!(a.rel_l2(&a) < 1e-9);
    }
}
