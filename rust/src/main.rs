//! `layermerge` — CLI entrypoint for the LayerMerge reproduction.
//!
//! Subcommands:
//!   compress --model M --budget F [--method layermerge|depth|layeronly|twostage]
//!   tables   --model M                 build lookup tables
//!   e2e      --model M --budget F      offline paper loop on the host
//!                                      backend: profile -> solve ->
//!                                      merge -> deploy -> measure,
//!                                      predicted vs actual latency
//!   frontier --model M                 budget sweep: speedup-vs-quality
//!                                      frontier for every method on
//!                                      shared host-measured tables
//!   table1..table11, fig1..fig5, all   regenerate paper tables/figures
//!   verify   --model M                 merged-vs-pruned numerics report
//!   profile  --model M                 per-format latency breakdown
//!   serve    --model M                 micro-batched serving load test
//!   serve-net --model M                TCP serving tier (admission
//!                                      control, shedding, deadlines)
//!   fleet    --model M                 multi-tenant budget-ladder fleet
//!                                      (weight dedup, DRR fairness,
//!                                      deadline routing)
//!   chaos    --model M                 deterministic fault drill: backend
//!                                      faults + flaky wire through the
//!                                      retrying client, invariant report
//!
//! Global flags: --artifacts DIR, --fast (analytical latency + short
//! schedules), --measured (pin measured latency, overrides --fast),
//! --force (ignore pretrain/table caches), --workers N, --pretrain N,
//! --finetune N, --seed N, --lat-warmup N, --lat-iters N,
//! --eval-batches N.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use layermerge::experiments::{figures, tables as exp_tables, Ctx};
use layermerge::pipeline::{Method, PipelineCfg};
use layermerge::runtime::Backend as _;
use layermerge::serve::net::{drive_net, NetCfg, NetServer};
use layermerge::serve::{self, BatchPolicy, LoadReport, ServeCfg, Session};
use layermerge::tables::LatencyMode;
use layermerge::util::tensor::Tensor;

/// Minimal flag parser (clap substitute; DESIGN.md §2).
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let looks_bool = matches!(key, "fast" | "measured" | "force");
                let val = if looks_bool {
                    "1".to_string()
                } else {
                    it.next().with_context(|| format!("--{key} needs a value"))?
                };
                flags.insert(key.to_string(), val);
            } else {
                bail!("unexpected argument {a}");
            }
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn usize_or(&self, k: &str, d: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }

    fn f64_or(&self, k: &str, d: f64) -> f64 {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }
}

fn usage() -> &'static str {
    "layermerge <cmd> [flags]\n\
     \n\
     commands:\n\
       compress   --model M --budget F [--method layermerge|depth|layeronly|twostage]\n\
       tables     --model M              build/load lookup tables\n\
       solve      --model M --budget F   solve on existing/host tables and\n\
                                         print the chosen spans (no\n\
                                         fine-tuning; works on both backends)\n\
       e2e        --model M --budget F   offline paper loop (host backend):\n\
                                         profile -> solve -> merge -> deploy\n\
                                         -> measure, reports predicted vs\n\
                                         actual latency + speedup\n\
       frontier   --model M --budgets F,F,..  sweep budget fractions for\n\
                                         LayerMerge / TwoStage / LayerOnly /\n\
                                         Channel on shared host tables and\n\
                                         record the frontier to EXPERIMENTS.md\n\
       verify     --model M              merged-vs-pruned numerics check\n\
       profile    --model M              per-format latency breakdown\n\
       serve      --model M              micro-batched serving load test\n\
       serve-net  --model M              TCP serving tier (deadline-aware\n\
                                         admission control + load shedding)\n\
       fleet      --model M              multi-tenant budget-ladder fleet:\n\
                                         shared-weight dedup, weighted-fair\n\
                                         scheduling, deadline-aware ladder\n\
                                         routing (host backend)\n\
       chaos      --model M              deterministic fault drill: injected\n\
                                         backend faults + a flaky loopback\n\
                                         wire, plain vs retrying client,\n\
                                         invariant report (host backend)\n\
       table1..table11                   regenerate a paper table\n\
       fig1..fig5                        regenerate a paper figure\n\
       all                               every table and figure\n\
     flags:\n\
       --backend pjrt|host  execution backend.  Default: host when the\n\
                         artifacts dir has no manifest.json (fresh\n\
                         checkout), else pjrt.  host runs the native\n\
                         kernels: no artifacts, no XLA — tables/solve/\n\
                         e2e/frontier/serve/profile work over the\n\
                         synthetic specs (hostnet, hostnet-tiny,\n\
                         hostchain, hostchain-tiny)\n\
       --artifacts DIR   (default ./artifacts)\n\
       --fast            analytical latency + short schedules (CI)\n\
       --measured        pin measured latency (overrides --fast)\n\
       --force           ignore cached pretrained weights and tables\n\
       --weight-format f32|int8  host-backend weight format: int8\n\
                         quantizes dense conv weights per output channel\n\
                         at lowering (activations stay f32).  Also\n\
                         settable via LM_WEIGHT_FORMAT; set\n\
                         LM_FORCE_SCALAR=1 to pin the scalar kernels\n\
       --workers N       importance-table worker threads\n\
       --lat-warmup N --lat-iters N      deployed-plan latency protocol\n\
       --eval-batches N                  eval-stream batches per metric\n\
       --pretrain N --finetune N --seed N --budget F --p N\n\
     serve flags:\n\
       --clients N       concurrent closed-loop clients (default 4)\n\
       --requests N      requests per client (default 32; total requests\n\
                         = clients x requests in open-loop mode)\n\
       --serve-workers N worker threads draining the queue\n\
       --queue-cap N     bounded request queue (backpressure)\n\
       --serve-policy P  batch former: greedy|window|adaptive (default\n\
                         greedy; window holds partial batches up to the\n\
                         window, adaptive tunes the window online)\n\
       --serve-window-us N  window bound / adaptive latency cap in us\n\
                         (default 2000)\n\
       --serve-occupancy F  adaptive target occupancy (default 0.75)\n\
       --arrival-rps F   open-loop mode: deterministic Poisson arrivals\n\
                         at F req/s instead of closed-loop clients\n\
       --slo-ms N        admission-control SLO: shed at the door when the\n\
                         predicted queue wait exceeds N ms (default 0 =\n\
                         no SLO shedding)\n\
     serve-net flags (plus the serve/session flags above):\n\
       --addr A          listen address (default 127.0.0.1:7433; use\n\
                         127.0.0.1:0 for an ephemeral port)\n\
       --conn-workers N  connection-handler threads (default 4)\n\
       --conns N         self-drive client connections (default 4)\n\
       --deadline-ms N   per-request deadline the self-drive clients\n\
                         attach (default 25; 0 = none)\n\
       with --arrival-rps F the command binds, self-drives F req/s of\n\
       open-loop Poisson load over loopback, prints the goodput/shed\n\
       report, and exits; without it the server listens until killed\n\
     chaos flags (plus the serve/session flags above):\n\
       --requests N      requests per arm (default 200)\n\
       --fault-rate F    per-request backend fault rate (default 0.05;\n\
                         compounded down to a per-op rate by plan depth)\n\
       --wire-rate F     total wire fault rate at the proxy (default\n\
                         0.10, split drop/stall/truncate/corrupt)\n\
       --retries N       retrying-client attempt budget (default 4)\n\
       --seed N          chaos seed (LM_CHAOS_SEED overrides)\n\
     fleet flags (plus the serve policy flags above):\n\
       --requests N      interactive-tenant request count (default 256;\n\
                         the batch tenant offers half)\n\
       --arrival-rps F   interactive-tenant arrival rate (default 120)\n\
       --deadline-ms N   interactive-tenant per-request deadline\n\
                         (default 25; 0 = none)\n"
}

/// `--method` flag shared by compress/solve on both backends.
fn parse_method(args: &Args) -> Result<Method> {
    match args.get("method").unwrap_or("layermerge") {
        "layermerge" => Ok(Method::LayerMerge),
        "depth" => Ok(Method::Depth),
        "layeronly" => Ok(Method::LayerOnly),
        "twostage" => Ok(Method::TwoStage),
        m => bail!("unknown method {m} (expected layermerge|depth|layeronly|twostage)"),
    }
}

fn build_cfg(args: &Args) -> Result<PipelineCfg> {
    let mut cfg = PipelineCfg::default();
    cfg.seed = args.usize_or("seed", 0) as u64;
    cfg.pretrain_steps = args.usize_or("pretrain", cfg.pretrain_steps);
    cfg.finetune_steps = args.usize_or("finetune", cfg.finetune_steps);
    cfg.p_disc = args.usize_or("p", cfg.p_disc);
    cfg.build.workers = args.usize_or("workers", cfg.build.workers);
    cfg.lat_warmup = args.usize_or("lat-warmup", cfg.lat_warmup);
    cfg.lat_iters = args.usize_or("lat-iters", cfg.lat_iters).max(1);
    cfg.eval_batches = args.usize_or("eval-batches", cfg.eval_batches).max(1);
    if args.get("fast").is_some() {
        std::env::set_var("LM_FAST", "1");
        cfg.build.mode = LatencyMode::Analytical;
    }
    if args.get("measured").is_some() {
        // wins over --fast: Ctx::new re-pins Measured via LM_MEASURED
        std::env::set_var("LM_MEASURED", "1");
        cfg.build.mode = LatencyMode::Measured;
    }
    if args.get("force").is_some() {
        cfg.force = true;
        cfg.build.force = true;
    }
    if let Some(wf) = args.get("weight-format") {
        // validated here, then carried by env like LM_FAST/LM_MEASURED:
        // HostBackend::new() reads LM_WEIGHT_FORMAT at construction
        layermerge::runtime::WeightFormat::parse(wf)
            .with_context(|| format!("unknown weight format {wf} (expected f32|int8)"))?;
        std::env::set_var("LM_WEIGHT_FORMAT", wf);
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    if args.cmd == "help" || args.cmd == "--help" {
        println!("{}", usage());
        return Ok(());
    }
    let repo = std::env::current_dir()?;
    let artifacts = PathBuf::from(
        args.get("artifacts").unwrap_or("artifacts"),
    );
    let cfg = build_cfg(&args)?;
    let host = match args.get("backend") {
        Some("host") => true,
        Some("pjrt") => false,
        Some(b) => bail!("unknown backend {b} (expected host|pjrt)"),
        // no flag: prefer the backend that can actually run — host when
        // the artifacts dir is absent (fresh checkout), pjrt otherwise
        None => !artifacts.join("manifest.json").exists(),
    };
    if host {
        // deployment-side commands on the native host backend: no
        // artifacts directory, no PJRT client, synthetic specs
        let ctx = Ctx::new_host(repo, cfg);
        let model = args.get("model").unwrap_or("hostnet");
        return match args.cmd.as_str() {
            "serve" => serve_host(&ctx, model, &args),
            "serve-net" => serve_net_host(&ctx, model, &args),
            "fleet" => fleet_host(&ctx, model, &args),
            "chaos" => chaos_host(&ctx, model, &args),
            "profile" => profile_host(&ctx, model),
            "tables" => tables_host(&ctx, model).map(|_| ()),
            "solve" => solve_host(&ctx, model, &args),
            "e2e" => e2e_cmd(&ctx, model, &args),
            "frontier" => frontier_cmd(&ctx, model, &args),
            other => bail!(
                "{other} needs the PJRT backend (gated graph / training); \
                 --backend host supports tables, solve, e2e, frontier, \
                 serve, serve-net, fleet, chaos, and profile"
            ),
        };
    }
    let ctx = Ctx::new(&artifacts, repo, cfg)?;

    match args.cmd.as_str() {
        "compress" => {
            let model = args.get("model").context("--model required")?;
            let budget = args.f64_or("budget", 0.65);
            let method = parse_method(&args)?;
            let mut pipe = ctx.pipeline(model)?;
            let c = pipe.run(method, budget)?;
            println!(
                "{} {}@{budget}: metric {:.4} (pruned {:.4}), depth {} -> {}, \
                 eager {:.2}ms ({:.2}x), fused {:.2}ms ({:.2}x)",
                model, c.method, c.merged_metric, c.pruned_metric,
                pipe.model.spec.len(), c.depth,
                c.lat_eager_ms, pipe.orig_lat_eager / c.lat_eager_ms,
                c.lat_fused_ms, pipe.orig_lat_fused / c.lat_fused_ms,
            );
        }
        "tables" => {
            let model = args.get("model").context("--model required")?;
            let mut pipe = ctx.pipeline(model)?;
            let t = pipe.ensure_tables()?;
            println!(
                "{model}: {} entries, orig ~{:.2}ms (fixed {:.2}ms), built lat {:.1}s imp {:.1}s",
                t.entries.len(), t.orig_ms(), t.fixed_ms, t.lat_build_s, t.imp_build_s
            );
        }
        "solve" => {
            let model = args.get("model").context("--model required")?;
            let mut pipe = ctx.pipeline(model)?;
            let sol = pipe.solve(parse_method(&args)?, args.f64_or("budget", 0.65))?;
            println!("{}", sol.summary());
        }
        "verify" => {
            let model = args.get("model").context("--model required")?;
            verify(&ctx, model, args.f64_or("budget", 0.65))?;
        }
        "profile" => {
            let model = args.get("model").context("--model required")?;
            profile(&ctx, model, args.f64_or("budget", 0.65))?;
        }
        "serve" => {
            let model = args.get("model").context("--model required")?;
            serve_cmd(&ctx, model, &args)?;
        }
        "serve-net" => {
            let model = args.get("model").context("--model required")?;
            serve_net_pjrt(&ctx, model, &args)?;
        }
        "fleet" | "e2e" | "frontier" => {
            bail!("{} runs on the native backend: pass --backend host", args.cmd)
        }
        "table1" => exp_tables::table1(&ctx)?,
        "table2" => exp_tables::table2(&ctx)?,
        "table3" => exp_tables::table3(&ctx)?,
        "table4" => exp_tables::table4(&ctx)?,
        "table5" => exp_tables::table5(&ctx)?,
        "table6" => exp_tables::table6(&ctx)?,
        "table7" => exp_tables::table7(&ctx)?,
        "table8" => exp_tables::table8(&ctx)?,
        "table9" => exp_tables::table9(&ctx)?,
        "table10" => exp_tables::table10(&ctx)?,
        "table11" => exp_tables::table11(&ctx)?,
        "fig1" => figures::fig1(&ctx)?,
        "fig2" => figures::fig2(&ctx)?,
        "fig3" => figures::fig3(&ctx)?,
        "fig4" => figures::fig4(&ctx)?,
        "fig5" => figures::fig5(&ctx)?,
        "all" => {
            exp_tables::all(&ctx)?;
            figures::all(&ctx)?;
        }
        other => {
            eprintln!("unknown command {other}\n{}", usage());
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Per-plan latency breakdown: original vs compressed, both formats, with
/// per-step device time — the §Perf profiling entrypoint for L3.
fn profile(ctx: &Ctx, model: &str, budget: f64) -> Result<()> {
    use layermerge::exec::{Format, Plan};
    let mut pipe = ctx.pipeline(model)?;
    let engine = ctx.engine();
    let sol = pipe.solve(Method::LayerMerge, budget)?;
    let orig = Arc::new(Plan::original(&pipe.model.spec, &pipe.pretrained)?);
    let comp = Arc::new(Plan::from_solution(&pipe.model.spec, &pipe.pretrained,
                                            &sol.a, &sol.c, &sol.spans)?);
    let sp = &pipe.model.spec;
    let mut rng = layermerge::util::rng::Rng::new(9);
    let n = sp.batch * sp.h * sp.w * sp.c;
    let x = Tensor::new(vec![sp.batch, sp.h, sp.w, sp.c],
                        (0..n).map(|_| rng.normal()).collect());
    let t = match sp.task {
        layermerge::ir::Task::Diffusion => Some(Tensor::full(&[sp.batch], 500.0)),
        _ => None,
    };
    for (name, plan) in [("original", &orig), ("compressed", &comp)] {
        for fmt in [Format::Eager, Format::Fused] {
            // lower once so the timed window is steady-state dispatch,
            // not per-call plan re-lowering
            let cp = engine.lower(plan, fmt)?;
            // warm
            for _ in 0..3 {
                cp.forward(&x, t.as_ref())?;
            }
            let mut best_total = f64::INFINITY;
            let mut best_dev = 0.0;
            for _ in 0..10 {
                let t0 = std::time::Instant::now();
                let (_, dev_ms) = cp.forward_timed(&x, t.as_ref())?;
                let total = t0.elapsed().as_secs_f64() * 1e3;
                if total < best_total {
                    best_total = total;
                    best_dev = dev_ms;
                }
            }
            println!(
                "{name:<12} {:?}: steps {:>2}, total {best_total:>8.2}ms, device {best_dev:>8.2}ms, host/glue {:>8.2}ms",
                fmt, plan.depth(), best_total - best_dev
            );
        }
    }
    println!("solution spans: {:?}", sol.spans);
    Ok(())
}

/// Merged-vs-pruned numerics: run the gated graph and the deployed plan on
/// the same batch and report the deviation (SAME-padding boundary effect —
/// DESIGN.md §4).
fn verify(ctx: &Ctx, model: &str, budget: f64) -> Result<()> {
    use layermerge::exec::{Format, Plan};
    let mut pipe = ctx.pipeline(model)?;
    let engine = ctx.engine();
    let sol = pipe.solve(Method::LayerMerge, budget)?;
    let a_set: std::collections::BTreeSet<usize> = sol.a.iter().copied().collect();
    let gates = pipe.model.spec.solution_gates(&a_set, &sol.c, &sol.spans);
    let plan = Arc::new(Plan::from_solution(&pipe.model.spec, &pipe.pretrained,
                                            &sol.a, &sol.c, &sol.spans)?);
    let batch = pipe.gen.batch(layermerge::train::STREAM_EVAL, 0);
    let (x, t) = match &batch {
        layermerge::model::Batch::Classify { x, .. } => (x.clone(), None),
        layermerge::model::Batch::Diffusion { x0, t, .. } => {
            (x0.clone(), Some(t.clone()))
        }
    };
    let gated = pipe.model.forward(&pipe.pretrained, &gates, &batch)?;
    let merged = engine.infer(&plan, &x, t.as_ref(), Format::Eager)?;
    let fused = engine.infer(&plan, &x, t.as_ref(), Format::Fused)?;
    println!(
        "verify {model} @{budget}: spans {:?}\n  merged-vs-gated  rel_l2 {:.4} max {:.4}\n  fused-vs-eager   rel_l2 {:.6} max {:.6}",
        sol.spans,
        merged.rel_l2(&gated), merged.max_abs_diff(&gated),
        fused.rel_l2(&merged), fused.max_abs_diff(&merged),
    );
    Ok(())
}

/// Parse the serve-policy flags into a [`BatchPolicy`].
fn serve_policy(args: &Args) -> Result<BatchPolicy> {
    let max_wait_us = args.usize_or("serve-window-us", 2000) as u64;
    match args.get("serve-policy").unwrap_or("greedy") {
        "greedy" => Ok(BatchPolicy::Greedy),
        "window" => Ok(BatchPolicy::Window { max_wait_us }),
        "adaptive" => Ok(BatchPolicy::Adaptive {
            target_occupancy: args.f64_or("serve-occupancy", 0.75),
            max_wait_us,
        }),
        p => bail!("unknown serve policy {p} (expected greedy|window|adaptive)"),
    }
}

/// Session sizing + policy from the serve flags.
fn serve_cfg(args: &Args) -> Result<ServeCfg> {
    let defaults = ServeCfg::default();
    let slo_ms = args.usize_or("slo-ms", 0) as u64;
    Ok(ServeCfg {
        workers: args.usize_or("serve-workers", defaults.workers).max(1),
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap).max(1),
        policy: serve_policy(args)?,
        // deployed CLI sessions pre-charge every worker's arena shard so
        // the first measured request is already in steady state
        warmup: true,
        // admission control: shed at the door once predicted queue wait
        // exceeds the SLO (0 = disabled)
        slo: (slo_ms > 0).then_some(std::time::Duration::from_millis(slo_ms)),
    })
}

/// Run one load pass: closed-loop clients by default, or deterministic
/// open-loop Poisson arrivals when `--arrival-rps` is set.
fn drive_session<F>(
    sess: &Session,
    clients: usize,
    requests: usize,
    rps: f64,
    make: F,
) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (Tensor, Option<Tensor>) + Sync,
{
    if rps > 0.0 {
        serve::drive_open(sess, rps, clients * requests, 0x0a11, make)
    } else {
        serve::drive(sess, clients, requests, make)
    }
}

/// Deploy the original and a compressed network as micro-batched serving
/// sessions and drive load against both (closed-loop clients, or
/// open-loop arrivals with `--arrival-rps`), reporting p50/p95,
/// throughput, occupancy, and the queue/service latency split before vs
/// after compression.
fn serve_cmd(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::{Format, Plan};
    let budget = args.f64_or("budget", 0.65);
    let clients = args.usize_or("clients", 4).max(1);
    let requests = args.usize_or("requests", 32).max(1);
    let rps = args.f64_or("arrival-rps", 0.0);
    let scfg = serve_cfg(args)?;
    let engine = ctx.engine();
    let mut pipe = ctx.pipeline(model)?;
    let pool = layermerge::serve::classify_request_pool(&pipe.gen, 4);
    anyhow::ensure!(
        !pool.is_empty(),
        "serve drives classifier models; {model} produced no classify rows"
    );
    println!(
        "serving {model}: {} single-row requests (spec batch {}, {} workers, \
         queue {}, policy {:?})",
        if rps > 0.0 {
            format!("open-loop {:.0} rps x {}", rps, clients * requests)
        } else {
            format!("{clients} clients x {requests}")
        },
        pipe.model.spec.batch,
        scfg.workers,
        scfg.queue_cap,
        scfg.policy,
    );
    let make = |c: usize, i: usize| {
        let (x, _) = &pool[(c * requests + i) % pool.len()];
        (x.clone(), None)
    };

    let orig_plan = Arc::new(Plan::original(&pipe.model.spec, &pipe.pretrained)?);
    let orig_sess = engine.deploy_cfg(orig_plan, Format::Fused, scfg)?;
    let r0 = drive_session(&orig_sess, clients, requests, rps, &make)?;
    println!("{}", r0.row(&format!("original {model}")));
    orig_sess.shutdown();

    let c = pipe.run(Method::LayerMerge, budget)?;
    let plan = Arc::new(Plan::from_solution(
        &pipe.model.spec, &c.finetuned, &c.solution.a, &c.solution.c,
        &c.solution.spans,
    )?);
    let sess = engine.deploy_cfg(plan, Format::Fused, scfg)?;
    let r1 = drive_session(&sess, clients, requests, rps, &make)?;
    println!("{}", r1.row(&format!("LayerMerge-{:.0}%", budget * 100.0)));
    println!(
        "  -> p50 {:.2}x, p95 {:.2}x, throughput {:.2}x",
        r0.p50_ms / r1.p50_ms,
        r0.p95_ms / r1.p95_ms,
        r1.rows_per_s / r0.rows_per_s,
    );
    sess.shutdown();
    Ok(())
}

/// Synthetic-spec plans for the host backend: the original network and
/// the table-free greedy depth-compressed cover.
fn host_plans(
    model: &str,
) -> Result<(layermerge::ir::Spec, Arc<layermerge::exec::Plan>, Arc<layermerge::exec::Plan>)> {
    use layermerge::exec::Plan;
    let (spec, params) = layermerge::ir::synth::by_name(model).with_context(|| {
        format!(
            "--backend host serves synthetic specs ({}); {model} unknown",
            layermerge::ir::synth::NAMES.join(", ")
        )
    })?;
    let orig = Arc::new(Plan::original(&spec, &params)?);
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(&spec);
    let merged = Arc::new(Plan::from_solution(&spec, &params, &a, &c, &spans)?);
    Ok((spec, orig, merged))
}

/// `serve --backend host`: deploy the original and greedy-merged
/// synthetic networks on the native host backend and drive concurrent
/// closed-loop clients against both — the paper's serving protocol,
/// exercisable offline.
fn serve_host(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::Format;
    use layermerge::util::rng::Rng;
    let clients = args.usize_or("clients", 4).max(1);
    let requests = args.usize_or("requests", 32).max(1);
    let rps = args.f64_or("arrival-rps", 0.0);
    let scfg = serve_cfg(args)?;
    let engine = ctx.engine();
    let (spec, orig, merged) = host_plans(model)?;
    println!(
        "serving {model} [host backend]: {} single-row requests (spec batch {}, \
         {} workers, queue {}, policy {:?})",
        if rps > 0.0 {
            format!("open-loop {:.0} rps x {}", rps, clients * requests)
        } else {
            format!("{clients} clients x {requests}")
        },
        spec.batch,
        scfg.workers,
        scfg.queue_cap,
        scfg.policy,
    );
    let mut rng = Rng::new(0x5e11);
    let row: usize = spec.h * spec.w * spec.c;
    let pool: Vec<Tensor> = (0..64)
        .map(|_| {
            Tensor::new(
                vec![1, spec.h, spec.w, spec.c],
                (0..row).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    let make = |c: usize, i: usize| (pool[(c * requests + i) % pool.len()].clone(), None);

    let orig_sess = engine.deploy_cfg(Arc::clone(&orig), Format::Fused, scfg)?;
    let r0 = drive_session(&orig_sess, clients, requests, rps, &make)?;
    println!("{}", r0.row(&format!("original {model}")));
    orig_sess.shutdown();

    let sess = engine.deploy_cfg(Arc::clone(&merged), Format::Fused, scfg)?;
    let r1 = drive_session(&sess, clients, requests, rps, &make)?;
    println!(
        "{}",
        r1.row(&format!("greedy-merged (depth {} -> {})", orig.depth(), merged.depth()))
    );
    println!(
        "  -> p50 {:.2}x, p95 {:.2}x, throughput {:.2}x",
        r0.p50_ms / r1.p50_ms,
        r0.p95_ms / r1.p95_ms,
        r1.rows_per_s / r0.rows_per_s,
    );
    sess.shutdown();
    Ok(())
}

/// Put the network tier in front of a deployed session: bind `--addr`,
/// then either self-drive open-loop Poisson load over loopback
/// (`--arrival-rps`, printing the goodput/shed report and both counter
/// sets) or listen until killed.
fn run_net_tier(sess: Session, args: &Args, pool: Vec<Tensor>) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:7433");
    let rps = args.f64_or("arrival-rps", 0.0);
    let requests = args.usize_or("requests", 256).max(1);
    let conns = args.usize_or("conns", 4).max(1);
    let deadline_ms = args.usize_or("deadline-ms", 25) as u64;
    let ncfg = NetCfg {
        conn_workers: args.usize_or("conn-workers", 4).max(1),
        ..NetCfg::default()
    };
    anyhow::ensure!(!pool.is_empty(), "serve-net: empty request pool");
    let session = Arc::new(sess);
    let server = NetServer::bind(Arc::clone(&session), addr, ncfg)?;
    println!(
        "serve-net listening on {} ({} conn workers, policy {:?})",
        server.addr(),
        ncfg.conn_workers,
        session.policy(),
    );
    if rps <= 0.0 {
        println!("no --arrival-rps: serving until killed (Ctrl-C)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let deadline =
        (deadline_ms > 0).then_some(std::time::Duration::from_millis(deadline_ms));
    let r = drive_net(server.addr(), rps, requests, conns, deadline, 0x5e7, |i| {
        (pool[i % pool.len()].clone(), None)
    })?;
    println!("{}", r.row("serve-net self-drive"));
    let s = session.stats();
    println!(
        "  session: {} batches ({} padded rows, occ {:.2}), shed {}, expired {}, \
         failed batches {}",
        s.batches, s.padded_rows, s.occupancy(), s.shed_requests,
        s.expired_requests, s.failed_batches,
    );
    let n = server.stats();
    println!(
        "  net: {} accepted ({} refused), {} frames -> {} replies, {} bad frames, \
         {} conn errors, {} handler panics",
        n.accepted, n.refused, n.frames, n.replies, n.bad_frames, n.conn_errors,
        n.handler_panics,
    );
    server.shutdown();
    if let Ok(s) = Arc::try_unwrap(session) {
        s.shutdown();
    }
    Ok(())
}

/// `serve-net --backend host`: the greedy-merged synthetic network behind
/// the TCP tier — the full deadline/shedding path, runnable offline.
fn serve_net_host(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::Format;
    use layermerge::util::rng::Rng;
    let scfg = serve_cfg(args)?;
    let engine = ctx.engine();
    let (spec, _orig, merged) = host_plans(model)?;
    let sess = engine.deploy_cfg(Arc::clone(&merged), Format::Fused, scfg)?;
    let mut rng = Rng::new(0x5e11);
    let row: usize = spec.h * spec.w * spec.c;
    let pool: Vec<Tensor> = (0..64)
        .map(|_| {
            Tensor::new(
                vec![1, spec.h, spec.w, spec.c],
                (0..row).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    run_net_tier(sess, args, pool)
}

/// `serve-net` on the PJRT backend: the original deployed plan behind the
/// TCP tier, fed single-row classify requests.
fn serve_net_pjrt(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::{Format, Plan};
    let scfg = serve_cfg(args)?;
    let engine = ctx.engine();
    let pipe = ctx.pipeline(model)?;
    let pool_xy = layermerge::serve::classify_request_pool(&pipe.gen, 4);
    anyhow::ensure!(
        !pool_xy.is_empty(),
        "serve-net drives classifier models; {model} produced no classify rows"
    );
    let plan = Arc::new(Plan::original(&pipe.model.spec, &pipe.pretrained)?);
    let sess = engine.deploy_cfg(plan, Format::Fused, scfg)?;
    let pool: Vec<Tensor> = pool_xy.into_iter().map(|(x, _)| x).collect();
    run_net_tier(sess, args, pool)
}

/// `fleet --backend host`: two tenants ("interactive", weight 3, tight
/// deadlines; "batch", weight 1, no deadlines) share one base model, each
/// deploying the same two-rung budget ladder — greedy-merged (cheap)
/// under the original (expensive) — through the fleet's shared weight
/// cache, so the second tenant's uploads dedup to `Arc` clones.  Drives
/// both arrival processes concurrently and prints per-tenant reports,
/// the dedup accounting, and the ladder router's hit/fallback/shed split.
fn fleet_host(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::Format;
    use layermerge::serve::fleet::{drive_fleet, Fleet, FleetCfg, FleetLoad, TenantCfg};
    use layermerge::util::rng::Rng;
    let requests = args.usize_or("requests", 256).max(1);
    let rps = args.f64_or("arrival-rps", 120.0).max(1.0);
    let deadline_ms = args.usize_or("deadline-ms", 25) as u64;
    let engine = ctx.engine();
    let (spec, orig, merged) = host_plans(model)?;
    let fleet = Fleet::new(FleetCfg::default());
    // seed the router's per-rung cost EWMA from the measured latency
    // tables (cached under the repo root), so the very first request
    // routes off real per-span costs; the online EWMA then refines the
    // seed from live dispatches
    let (_, flat) = layermerge::ir::synth::by_name(model).expect("checked by host_plans");
    let t = layermerge::tables::build_host(
        &spec, &flat, engine.backend(), &ctx.cfg.build, &ctx.repo,
    )?;
    println!(
        "  rung cost seeds from tables: merged {}us, original {}us",
        t.plan_seed_us(&merged),
        t.plan_seed_us(&orig),
    );
    for (name, weight) in [("interactive", 3usize), ("batch", 1)] {
        fleet.add_tenant(TenantCfg::new(name, weight, serve_policy(args)?))?;
        fleet.deploy_seeded(name, &engine, &merged, Format::Fused, &t)?;
        fleet.deploy_seeded(name, &engine, &orig, Format::Fused, &t)?;
    }
    let fs = fleet.stats();
    println!(
        "fleet {model} [host backend]: {} tenants x 2-rung ladder (depth {} / {}), \
         {:.1} KiB unique weights, {:.1} KiB deduped away",
        fs.tenants,
        merged.depth(),
        orig.depth(),
        fs.unique_weight_bytes as f64 / 1024.0,
        fs.dedup_saved_bytes as f64 / 1024.0,
    );
    let mut rng = Rng::new(0x5e11);
    let row: usize = spec.h * spec.w * spec.c;
    let pool: Vec<Tensor> = (0..64)
        .map(|_| {
            Tensor::new(
                vec![1, spec.h, spec.w, spec.c],
                (0..row).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    let deadline =
        (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
    let loads = vec![
        FleetLoad {
            tenant: "interactive".into(),
            rps,
            requests,
            deadline,
            seed: 0xf1ee7,
        },
        FleetLoad {
            tenant: "batch".into(),
            rps: (rps / 2.0).max(1.0),
            requests: (requests / 2).max(1),
            deadline: None,
            seed: 0xba7c4,
        },
    ];
    let reports =
        drive_fleet(&fleet, &loads, |_, i| (pool[i % pool.len()].clone(), None))?;
    for (l, r) in loads.iter().zip(&reports) {
        println!("{}", r.row(&l.tenant));
    }
    let rs = fleet.router_stats();
    println!(
        "  router: {} hits, {} fallbacks, {} sheds (cheapest-rung hit-rate {:.2})",
        rs.hits,
        rs.fallbacks,
        rs.sheds,
        rs.hit_rate(),
    );
    fleet.shutdown();
    Ok(())
}

/// `chaos --backend host`: a deterministic end-to-end fault drill.  The
/// greedy-merged plan is deployed twice over the TCP tier — once clean,
/// once on a `FaultBackend` (injected op failures and panics) behind a
/// flaky loopback `FaultProxy` (dropped connections, stalls, truncated
/// and corrupted frames) — and driven with a plain client vs the
/// retrying client.  Prints the invariant report: every request
/// resolves exactly once, the server counters partition the dispatched
/// work, and the retrying client's goodput retention vs the clean
/// baseline.  Seeded via `--seed` / `LM_CHAOS_SEED` so a run is
/// reproducible.
fn chaos_host(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    use layermerge::exec::Format;
    use layermerge::runtime::HostBackend;
    use layermerge::serve::chaos::{
        self, FaultBackend, FaultPlan, FaultProxy, FaultSpec, WireFaults,
    };
    use layermerge::serve::net::{NetClient, RetryClient, RetryPolicy};
    use layermerge::serve::Engine;
    use layermerge::util::rng::Rng;

    let requests = args.usize_or("requests", 200).max(1);
    let fault_rate = args.f64_or("fault-rate", 0.05).clamp(0.0, 0.9);
    let wire_rate = args.f64_or("wire-rate", 0.10).clamp(0.0, 0.9);
    let retries = args.usize_or("retries", 4).max(1);
    let seed = chaos::env_seed(args.usize_or("seed", 0xC4A05) as u64);
    let (spec, _orig, merged) = host_plans(model)?;

    let mut rng = Rng::new(seed ^ 0x5e11);
    let row: usize = spec.h * spec.w * spec.c;
    let pool: Vec<Tensor> = (0..64)
        .map(|_| {
            Tensor::new(
                vec![1, spec.h, spec.w, spec.c],
                (0..row).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    let bind = |sess: Session| {
        NetServer::bind(Arc::new(sess), "127.0.0.1:0", NetCfg::default())
    };

    // arm 1: fault-free baseline over a clean wire
    let clean = match bind(ctx.engine().deploy_cfg(
        Arc::clone(&merged),
        Format::Fused,
        serve_cfg(args)?,
    )?) {
        Ok(s) => s,
        Err(e) => {
            println!("chaos drill needs a loopback socket: {e:#}");
            return Ok(());
        }
    };
    let mut base_ok = 0usize;
    {
        let mut c = NetClient::connect(clean.addr())?;
        for i in 0..requests {
            if matches!(c.infer_deadline(&pool[i % pool.len()], None, None), Ok(Ok(_))) {
                base_ok += 1;
            }
        }
    }
    clean.shutdown();

    // arm 2: injected backend faults + a flaky wire, retrying client.
    // The backend fires per dispatched op, so the per-request rate is
    // compounded down to a per-op rate by the plan depth.
    let ops = merged.depth().max(1);
    let p_op = 1.0 - (1.0 - fault_rate).powf(1.0 / ops as f64);
    let fplan = FaultPlan::random(
        FaultSpec { fail: p_op * 0.8, panic: p_op * 0.2, delay: 0.0, delay_ms: 0 },
        seed,
    );
    let engine = Engine::with_backend(Arc::new(FaultBackend::wrap(
        Arc::new(HostBackend::new()),
        Arc::clone(&fplan),
    )));
    let server = bind(engine.deploy_cfg(Arc::clone(&merged), Format::Fused, serve_cfg(args)?)?)
        .context("chaos drill: rebind for the faulty arm")?;
    let wire = WireFaults {
        drop_conn: wire_rate * 0.4,
        stall: wire_rate * 0.2,
        stall_ms: 5,
        truncate: wire_rate * 0.2,
        corrupt: wire_rate * 0.2,
    };
    let proxy = FaultProxy::bind(server.addr(), wire, seed ^ 0x717e)?;
    println!(
        "chaos {model} [host backend]: {requests} requests/arm, backend fault rate \
         {fault_rate:.2}/request ({p_op:.4}/op x {ops} ops), wire fault rate \
         {wire_rate:.2}/frame, {retries}-attempt retry budget, seed {seed:#x}",
    );
    let mut rc = RetryClient::new(proxy.addr())
        .with_retry(RetryPolicy { attempts: retries, base_ms: 2, cap_ms: 50 })
        .with_seed(seed ^ 0x2e72);
    let (mut ok, mut server_err, mut transport_err) = (0usize, 0usize, 0usize);
    for i in 0..requests {
        match rc.infer_deadline(&pool[i % pool.len()], None, None) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(_)) => server_err += 1,
            Err(_) => transport_err += 1,
        }
    }
    let rstats = rc.retry_stats();
    let fc = fplan.counts();
    let wc = proxy.counts();
    let stats = server.session().stats();
    proxy.shutdown();
    server.shutdown();

    println!(
        "  baseline: {base_ok}/{requests} ok | chaos: {ok} ok, {server_err} typed \
         server errors, {transport_err} transport failures"
    );
    println!(
        "  injected: {} backend faults over {} op events ({} failed, {} panicked); \
         wire: {} conns, {} forwarded, {} dropped, {} stalled, {} truncated, {} corrupted",
        fc.injected(), fc.events, fc.failed, fc.panicked,
        wc.conns, wc.forwarded, wc.dropped, wc.stalled, wc.truncated, wc.corrupted,
    );
    println!(
        "  client: {} attempts, {} retries, {} hedges; server: {} dispatched, {} shed, \
         {} expired, {} failed batches ({} panicked)",
        rstats.attempts, rstats.retries, rstats.hedges,
        stats.requests, stats.shed_requests, stats.expired_requests,
        stats.failed_batches, stats.panicked_batches,
    );
    let resolved = ok + server_err + transport_err;
    let retention = ok as f64 / (base_ok as f64).max(1.0);
    println!(
        "  invariants: {resolved}/{requests} requests resolved exactly once ({}), \
         panicked <= failed batches ({}), goodput retention {retention:.2} ({})",
        if resolved == requests { "OK" } else { "VIOLATED" },
        if stats.panicked_batches <= stats.failed_batches { "OK" } else { "VIOLATED" },
        if retention >= 0.9 { "OK: >= 0.90" } else { "below 0.90" },
    );
    anyhow::ensure!(resolved == requests, "a request vanished without a verdict");
    Ok(())
}

/// `tables --backend host`: build (or load from cache) the lookup tables
/// for a synthetic spec by measuring real span kernels on the native
/// backend — the same `(i, j, k)` surrogate the PJRT arm builds, with no
/// artifacts and no XLA.  Returns the tables for `solve`/`frontier`.
fn tables_host(ctx: &Ctx, model: &str) -> Result<layermerge::tables::Tables> {
    use layermerge::runtime::HostBackend;
    let (spec, flat) = layermerge::ir::synth::by_name(model).with_context(|| {
        format!(
            "--backend host builds tables for synthetic specs ({}); {model} unknown",
            layermerge::ir::synth::NAMES.join(", ")
        )
    })?;
    let backend: Arc<dyn layermerge::runtime::Backend> = Arc::new(HostBackend::new());
    let t = layermerge::tables::build_host(&spec, &flat, &backend, &ctx.cfg.build, &ctx.repo)?;
    println!(
        "{model} [host backend]: {} entries, orig ~{:.2}ms (fixed {:.2}ms), \
         built lat {:.1}s imp {:.1}s",
        t.entries.len(), t.orig_ms(), t.fixed_ms, t.lat_build_s, t.imp_build_s
    );
    Ok(t)
}

/// `solve --backend host`: solve the surrogate problem on host-built
/// tables and print the chosen spans — no training anywhere in the loop.
fn solve_host(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    let t = tables_host(ctx, model)?;
    let (spec, _) = layermerge::ir::synth::by_name(model).expect("checked by tables_host");
    let method = parse_method(args)?;
    let sol = layermerge::pipeline::solve_tables(
        &spec, &t, method, args.f64_or("budget", 0.65), ctx.cfg.p_disc,
    )?;
    println!("{} {}", method.name(), sol.summary());
    Ok(())
}

/// `e2e --backend host`: the full offline paper loop — profile real span
/// kernels into tables, solve Algorithm 1 (and the predecessor's
/// two-stage DP on the same instance), merge, deploy, and measure the
/// deployed plan — then report how well the table-sum prediction matched
/// the measured latency.
fn e2e_cmd(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    let budget = args.f64_or("budget", 0.65);
    let r = layermerge::pipeline::e2e_host(model, budget, &ctx.cfg, &ctx.repo)?;
    println!(
        "e2e {model} @{budget} [host backend]{}:",
        ctx.mode_tag()
    );
    println!(
        "  kernels   : isa {}  weight-format {}",
        r.isa, r.weight_format
    );
    println!(
        "  original  : pred {:.4}ms  actual {:.4}ms  depth {}",
        r.pred_orig_ms, r.actual_orig_ms, r.depth_before
    );
    println!(
        "  merged    : pred {:.4}ms  actual {:.4}ms  depth {}  spans {:?}",
        r.pred_merged_ms, r.actual_merged_ms, r.depth_after, r.spans
    );
    println!(
        "  speedup   : pred {:.2}x  actual {:.2}x  (pred-vs-actual err {:.1}%)",
        r.pred_speedup(), r.actual_speedup(), r.rel_err() * 100.0
    );
    println!(
        "  solvers   : alg1 obj {:.4} in {:.2}ms | two-stage obj {:.4} in {:.2}ms",
        r.dp_objective, r.dp_solve_ms, r.twostage_objective, r.twostage_solve_ms
    );
    Ok(())
}

/// `frontier --backend host`: sweep `--budgets` and emit the
/// speedup-vs-quality frontier (LayerMerge / TwoStage / LayerOnly on
/// shared host tables, plus the channel-pruning reference) to stdout and
/// EXPERIMENTS.md.
fn frontier_cmd(ctx: &Ctx, model: &str, args: &Args) -> Result<()> {
    let fracs: Vec<f64> = match args.get("budgets") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<f64>().with_context(|| format!("bad budget {p:?}")))
            .collect::<Result<_>>()?,
        None => vec![0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
    };
    anyhow::ensure!(!fracs.is_empty(), "--budgets parsed to an empty list");
    let pts = layermerge::report::frontier::emit(
        model, &fracs, &ctx.cfg.build, ctx.cfg.p_disc, &ctx.repo, &ctx.experiments_md(),
    )?;
    let feasible = pts.iter().filter(|p| p.feasible).count();
    println!(
        "frontier {model}: {} points ({} feasible) -> {}",
        pts.len(), feasible, ctx.experiments_md().display()
    );
    Ok(())
}

/// `profile --backend host`: per-format end-to-end latency of the
/// original vs greedy-merged synthetic network through
/// `CompiledPlan::measure`, plus the steady-state transfer counts.
fn profile_host(ctx: &Ctx, model: &str) -> Result<()> {
    use layermerge::exec::Format;
    let engine = ctx.engine();
    let (_, orig, merged) = host_plans(model)?;
    let (w, it) = (ctx.cfg.lat_warmup, ctx.cfg.lat_iters);
    println!(
        "profile {model} [host backend, isa {}, weights {}] ({w} warmup, {it} iters):",
        layermerge::kernels::isa().name(),
        engine.backend().weight_format().name(),
    );
    for (name, plan) in [("original", &orig), ("greedy-merged", &merged)] {
        for fmt in [Format::Eager, Format::Fused] {
            let cp = engine.lower(plan, fmt)?;
            let be = engine.backend();
            let (u0, d0) = (be.uploads(), be.downloads());
            let stats = cp.measure(w, it)?;
            let per = (w + it).max(1);
            println!(
                "{name:<14} {fmt:?}: steps {:>2}, p50 {:>8.3}ms p95 {:>8.3}ms \
                 ({:.1} uploads + {:.1} downloads / forward)",
                plan.depth(),
                stats.p50_ms,
                stats.p95_ms,
                (be.uploads() - u0) as f64 / per as f64,
                (be.downloads() - d0) as f64 / per as f64,
            );
        }
    }
    Ok(())
}
