#!/usr/bin/env bash
# Tier-1 CI gate: release build + host test suite + formatting check +
# a BENCH_SMOKE=1 bench pass (tiny shapes, no JSON write) so bench code
# is compile-and-run gated instead of rotting until the next perf PR.
#
# Usage: scripts/ci.sh
#   CI_SKIP_FMT=1 scripts/ci.sh      # skip the rustfmt check (e.g. no rustfmt)
#   CI_SKIP_CLIPPY=1 scripts/ci.sh   # skip the clippy gate (e.g. no clippy)
#
# No network, artifacts, or system XLA needed: the workspace resolves
# `anyhow`/`xla` to in-tree path crates and artifact-dependent suites
# self-skip (see rust/tests/common/mod.rs).
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# the network-tier suite is part of the line above; run it by name too so
# a filtered/partial test invocation can never silently drop the
# robustness gate (loopback-unavailable environments self-skip)
echo "== cargo test -q --test serve_net =="
cargo test -q --test serve_net

# same treatment for the multi-tenant fleet suite (dedup accounting,
# weighted fairness, deadline routing, hot swap, pool lifecycle)
echo "== cargo test -q --test fleet =="
cargo test -q --test fleet

# chaos suite by name: exactly-once tickets under injected faults, the
# retrying/hedging client through a flaky wire, rung quarantine +
# re-admission (loopback-unavailable environments self-skip)
echo "== cargo test -q --test chaos =="
cargo test -q --test chaos

# solver-baseline equivalence by name: the predecessor's two-stage DP
# must match Algorithm 1's objective on every random instance
echo "== cargo test -q --test baselines =="
cargo test -q --test baselines

# backend-generic profiling layer by name: measure_span vs a deployed
# single-span plan, plus the offline e2e loop's pred-vs-actual bound
echo "== cargo test -q --test profile =="
cargo test -q --test profile

# kernel parity twice: once on the natively detected ISA, once with the
# process pinned to the scalar kernels.  available_isas() ignores
# LM_FORCE_SCALAR, so the pinned run still cross-checks the vector
# kernels against the scalar oracle — both dispatch configurations are
# exercised no matter which machine CI lands on.
echo "== cargo test -q --test gemm_parity (native ISA) =="
cargo test -q --test gemm_parity
echo "== LM_FORCE_SCALAR=1 cargo test -q --test gemm_parity =="
LM_FORCE_SCALAR=1 cargo test -q --test gemm_parity

# int8 weight-format gates by name: end-to-end accuracy delta vs the f32
# forward and the zero-allocation steady state on the quantized path
echo "== cargo test -q --test steady_state =="
cargo test -q --test steady_state

# the offline paper loop through the CLI: measured host tables -> DP ->
# merge -> deploy -> measure, no artifacts and no XLA anywhere
echo "== e2e smoke (host backend) =="
BENCH_SMOKE=1 cargo run --release --quiet -- e2e \
    --backend host --model hostchain-tiny --budget 0.6 \
    --lat-warmup 1 --lat-iters 3 --force

# a short fixed-seed chaos soak through the CLI drill: the whole stack
# (FaultBackend engine -> TCP tier -> FaultProxy -> RetryClient) under a
# pinned seed, so the invariant report is reproducible run to run
echo "== LM_CHAOS_SEED pinned chaos soak (CLI drill) =="
LM_CHAOS_SEED=0x5eedc4a0 cargo run --release --quiet -- chaos \
    --backend host --model hostnet-tiny --requests 40

# serving hot paths must use the poison-recovering lock helpers
# (serve::plock / pwait / pwait_timeout / punwrap), never a bare
# `.lock().unwrap()` that turns one poisoned batch into a cascade
echo "== serve lock-hygiene lint =="
if grep -rn --include='*.rs' -e '\.lock()\.unwrap()' -e '\.lock()\.expect(' src/serve/; then
    echo "error: bare lock().unwrap()/expect() in src/serve/ — use serve::plock and friends" >&2
    exit 1
fi

if [ "${CI_SKIP_CLIPPY:-0}" != "1" ] && cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy skipped (clippy unavailable or CI_SKIP_CLIPPY=1) =="
fi

if [ "${CI_SKIP_FMT:-0}" != "1" ] && cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check skipped (rustfmt unavailable or CI_SKIP_FMT=1) =="
fi

echo "== BENCH_SMOKE=1 scripts/bench.sh (bench compile-and-run gate) =="
BENCH_SMOKE=1 "$SCRIPT_DIR/bench.sh"

echo "CI OK"
