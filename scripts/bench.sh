#!/usr/bin/env bash
# Run the merge/forward perf benches and write BENCH_merge.json at the
# repo root (stable schema "layermerge.bench.merge.v1" — one record per
# PR lets the perf trajectory be compared across sessions).
#
# Usage:
#   scripts/bench.sh              # merge benches (host-only, no artifacts)
#   make artifacts && scripts/bench.sh   # adds span_merge + forward rows
#   BENCH_OUT=/tmp/b.json scripts/bench.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo bench --bench merge_ops ${1:+"$@"}
