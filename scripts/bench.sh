#!/usr/bin/env bash
# Run the perf benches and write BENCH_merge.json at the repo root
# (stable schema "layermerge.bench.merge.v1" — one record per PR lets the
# perf trajectory be compared across sessions).
#
#   * merge_ops        — flat-GEMM vs naive merge, eager vs compiled
#     forward (writes the base record)
#   * runtime_dispatch — device-resident vs per-dispatch forward on the
#     host backend, with transfer counts (the `resident_forward` record;
#     read-modify-write)
#   * serving          — micro-batched Session throughput at 1/4/16
#     concurrent clients, window-policy comparison, the TCP tier
#     over loopback at 0.5x/1x/2x capacity (`serving_net`: goodput,
#     shed rate, p99-of-admitted; skips cleanly with no loopback),
#     and the multi-tenant fleet (`fleet_*`: weight-dedup bytes,
#     routed-vs-pinned-biggest goodput) (read-modify-write)
#   * solvers          — Algorithm 1 vs the predecessor's two-stage DP
#     vs the LayerOnly knapsack at paper scale
#     (`twostage_vs_dp_*`), plus one offline e2e loop on measured
#     host tables (`e2e_pred_vs_actual_err`) (read-modify-write)
#
# Usage:
#   scripts/bench.sh              # host-only benches, no artifacts needed
#   make artifacts && scripts/bench.sh   # adds span_merge + forward +
#                                        # deployed-plan serving rows
#   BENCH_OUT=/tmp/b.json scripts/bench.sh
#   BENCH_SMOKE=1 scripts/bench.sh       # CI fast path: tiny iters and
#                                        # shapes, no BENCH_merge.json
#                                        # write — compile-and-run gate
set -euo pipefail
cd "$(dirname "$0")/../rust"
cargo bench --bench merge_ops ${1:+"$@"}
cargo bench --bench runtime_dispatch
cargo bench --bench serving
cargo bench --bench solvers
