//! Diffusion scenario — compress the DDPM-style U-Net, then generate
//! images with DDIM sampling through the gated graph and score them with
//! FDD (the Table 4 workload).
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_diffusion
//! ```

use layermerge::experiments::{figures, Ctx};
use layermerge::pipeline::{Method, PipelineCfg};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(std::path::Path::new("artifacts"),
                       std::env::current_dir()?, PipelineCfg::default())?;
    let mut pipe = ctx.pipeline("ddpmish")?;
    println!(
        "ddpmish: {} convs, diffusion loss {:.4}, eager {:.2}ms",
        pipe.model.spec.len(), -pipe.orig_metric, pipe.orig_lat_eager
    );
    let fdd0 = figures::fdd_of_gates(
        &ctx, &pipe, &pipe.pretrained.clone(), &pipe.model.spec.pristine_gates())?;
    println!("original FDD (8-step DDIM samples vs data): {fdd0:.3}\n");

    for budget in [0.9, 0.75] {
        let c = pipe.run(Method::LayerMerge, budget)?;
        let fdd = figures::fdd_of_gates(&ctx, &pipe, &c.finetuned, &c.gates)?;
        println!(
            "LayerMerge-{:.0}%: depth {} -> {}, diff loss {:.4}, FDD {:.3}, \
             eager {:.2}x, fused {:.2}x\n",
            budget * 100.0,
            pipe.model.spec.len(),
            c.depth,
            -c.merged_metric,
            fdd,
            pipe.orig_lat_eager / c.lat_eager_ms,
            pipe.orig_lat_fused / c.lat_fused_ms,
        );
    }
    Ok(())
}
