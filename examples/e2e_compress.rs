//! End-to-end validation driver (the DESIGN.md §5 headline run).
//!
//! Exercises every layer of the stack on a real small workload:
//!   1. pretrain the resnetish classifier on the synthetic task through
//!      the AOT train-step graph (L2+L1 lowered, L3 driving),
//!   2. build the measured latency + importance tables through PJRT,
//!   3. solve Algorithm 1 at three budgets,
//!   4. fine-tune each pruned network, merge (parameter-space convolution
//!      with Dirac folding), deploy,
//!   5. verify merged-vs-pruned numerics and fused-vs-eager equivalence,
//!   6. measure real wall-clock latency in both formats,
//!   7. record the Table-1-shaped rows into EXPERIMENTS.md §e2e.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_compress
//! ```

use std::sync::Arc;

use layermerge::exec::{Format, Plan};
use layermerge::experiments::Ctx;
use layermerge::pipeline::{Method, PipelineCfg};
use layermerge::report;
use layermerge::train;

fn main() -> anyhow::Result<()> {
    let repo = std::env::current_dir()?;
    let ctx = Ctx::new(std::path::Path::new("artifacts"), repo.clone(),
                       PipelineCfg::default())?;
    let engine = ctx.engine();
    let mut pipe = ctx.pipeline("resnetish")?;
    let mut t = report::compression_table(
        "E2E — resnetish compressed at three budgets (measured latencies)",
        true,
    );
    t.row(vec![
        "resnetish (original)".into(),
        format!("{:.2}", pipe.orig_metric * 100.0),
        "1.00x".into(),
        "1.00x".into(),
        format!("{}", pipe.model.spec.len()),
        "0.00".into(),
    ]);

    let mut verify_lines = String::new();
    for budget in [0.8, 0.65, 0.5] {
        let sol = pipe.solve(Method::LayerMerge, budget)?;
        println!("budget {budget}: {}", sol.summary());
        let c = pipe.finetune_and_deploy(Method::LayerMerge, budget, &sol, None, false)?;

        // numerics: pruned gated graph vs deployed merged plan
        let a_set: std::collections::BTreeSet<usize> = sol.a.iter().copied().collect();
        let gates = pipe.model.spec.solution_gates(&a_set, &sol.c, &sol.spans);
        let plan = Arc::new(Plan::from_solution(&pipe.model.spec, &c.finetuned,
                                                &sol.a, &sol.c, &sol.spans)?);
        let batch = pipe.gen.batch(train::STREAM_EVAL, 0);
        let x = match &batch {
            layermerge::model::Batch::Classify { x, .. } => x.clone(),
            _ => unreachable!(),
        };
        let gated = pipe.model.forward(&c.finetuned, &gates, &batch)?;
        let eager = engine.infer(&plan, &x, None, Format::Eager)?;
        let fused = engine.infer(&plan, &x, None, Format::Fused)?;
        let pad_dev = eager.rel_l2(&gated);
        let fmt_dev = fused.rel_l2(&eager);
        anyhow::ensure!(fmt_dev < 1e-4,
            "fused and eager formats must agree, got rel_l2 {fmt_dev}");
        verify_lines.push_str(&format!(
            "- budget {budget}: merged-vs-pruned logits rel_l2 {pad_dev:.4} \
             (SAME-padding reorder boundary effect, DESIGN.md §4); \
             fused-vs-eager rel_l2 {fmt_dev:.2e}; \
             pruned acc {:.2}%, merged acc {:.2}%\n",
            c.pruned_metric * 100.0, c.merged_metric * 100.0,
        ));
        t.row(report::row(&c, pipe.orig_metric, pipe.orig_lat_eager,
                          pipe.orig_lat_fused, true));
    }
    t.print();
    println!("{verify_lines}");
    let body = format!("{}\n**Numerics verification**\n\n{}", t.markdown(), verify_lines);
    report::record(&repo.join("EXPERIMENTS.md"), "e2e", &body)?;
    println!("recorded to EXPERIMENTS.md §e2e");
    Ok(())
}
