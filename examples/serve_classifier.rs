//! Serving scenario — deploy a compressed classifier behind the
//! micro-batched [`Session`] queue and serve a concurrent request stream,
//! reporting p50/p95 latency and throughput before/after compression.
//! This is the "latency-critical application" workload the paper's
//! introduction motivates (mobile / self-driving inference).
//!
//! Each deployed network is lowered **once** (`Engine::deploy`) into an
//! owned, `Send + Sync` [`CompiledPlan`]; a pool of worker threads
//! coalesces single-image client requests up to the spec batch size and
//! splits the results back per ticket.  The serving hot path is nothing
//! but PJRT dispatches — zero artifact lookups or cache-mutex
//! acquisitions per request.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_classifier
//! ```

use std::sync::Arc;

use layermerge::exec::{Format, Plan};
use layermerge::experiments::Ctx;
use layermerge::pipeline::{host_accuracy, Method, Pipeline, PipelineCfg};
use layermerge::serve::{self, Engine, ServeCfg, Session};

/// Requests per client at each concurrency level.
const REQUESTS: usize = 32;
const CLIENT_LEVELS: [usize; 3] = [1, 4, 16];

/// Drive `clients` concurrent single-image submitters and print one row.
fn load_row(
    name: &str,
    sess: &Session,
    pool: &[(layermerge::util::tensor::Tensor, layermerge::util::tensor::Tensor)],
    clients: usize,
) -> anyhow::Result<serve::LoadReport> {
    let r = serve::drive(sess, clients, REQUESTS, |c, i| {
        (pool[(c * REQUESTS + i) % pool.len()].0.clone(), None)
    })?;
    println!("{}", r.row(name));
    Ok(r)
}

/// Accuracy through the queue: submit every pooled row, score each ticket
/// against its label (also exercises sub-batch ticket delivery).
fn queued_accuracy(
    sess: &Session,
    pool: &[(layermerge::util::tensor::Tensor, layermerge::util::tensor::Tensor)],
) -> anyhow::Result<f32> {
    let tickets: Vec<_> = pool
        .iter()
        .map(|(x, _)| sess.submit(x.clone()))
        .collect::<anyhow::Result<_>>()?;
    let mut acc = 0.0f32;
    for (t, (_, y)) in tickets.into_iter().zip(pool) {
        acc += host_accuracy(&t.wait()?, y);
    }
    Ok(acc / pool.len() as f32)
}

fn serve_network(
    name: &str,
    engine: &Engine,
    plan: Arc<Plan>,
    pipe: &Pipeline,
) -> anyhow::Result<Vec<serve::LoadReport>> {
    let sess = engine.deploy_cfg(plan, Format::Fused, ServeCfg::default())?;
    let pool = serve::classify_request_pool(&pipe.gen, 4);
    // warm the executables before timing
    for (x, _) in pool.iter().take(sess.batch()) {
        sess.submit(x.clone())?.wait()?;
    }
    let acc = queued_accuracy(&sess, &pool)?;
    let mut reports = Vec::new();
    for clients in CLIENT_LEVELS {
        reports.push(load_row(
            &format!("{name} c{clients}"),
            &sess,
            &pool,
            clients,
        )?);
    }
    let s = sess.stats();
    println!(
        "  acc {:.1}%  |  {} requests in {} batches, {} padded rows, queue peak {}\n",
        acc * 100.0,
        s.requests,
        s.batches,
        s.padded_rows,
        s.max_queue
    );
    sess.shutdown();
    Ok(reports)
}

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(std::path::Path::new("artifacts"),
                       std::env::current_dir()?, PipelineCfg::default())?;
    let engine = ctx.engine();
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;

    println!(
        "micro-batched serving: {:?} concurrent clients x {REQUESTS} single-image \
         requests (spec batch {})\n",
        CLIENT_LEVELS, pipe.model.spec.batch
    );
    let orig = Arc::new(Plan::original(&pipe.model.spec, &pipe.pretrained)?);
    let base = serve_network("original mnv2ish-1.0", &engine, orig, &pipe)?;

    for budget in [0.65, 0.5] {
        let c = pipe.run(Method::LayerMerge, budget)?;
        let plan = Arc::new(Plan::from_solution(
            &pipe.model.spec, &c.finetuned, &c.solution.a, &c.solution.c,
            &c.solution.spans,
        )?);
        let depth = plan.depth();
        let name = format!("LayerMerge-{:.0}%", budget * 100.0);
        let comp = serve_network(&name, &engine, plan, &pipe)?;
        for (b, r) in base.iter().zip(&comp) {
            println!(
                "  {name} c{}: p50 {:.2}x, p95 {:.2}x, throughput {:.2}x \
                 (depth {} -> {depth})",
                r.clients,
                b.p50_ms / r.p50_ms,
                b.p95_ms / r.p95_ms,
                r.rows_per_s / b.rows_per_s,
                pipe.model.spec.len(),
            );
        }
        println!();
    }
    Ok(())
}
