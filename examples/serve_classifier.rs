//! Serving scenario — deploy a compressed classifier and serve a request
//! stream, reporting throughput and latency percentiles before/after
//! compression.  This is the "latency-critical application" workload the
//! paper's introduction motivates (mobile / self-driving inference).
//!
//! Each deployed network is lowered **once** to a [`CompiledPlan`] and the
//! request loop runs on it: zero artifact lookups, cache-mutex
//! acquisitions, or boundary-tensor clones per request — the serving hot
//! path is nothing but PJRT dispatches.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_classifier
//! ```

use std::time::Instant;

use layermerge::exec::{CompiledPlan, Format, Plan};
use layermerge::experiments::Ctx;
use layermerge::pipeline::{host_accuracy, Method, PipelineCfg};
use layermerge::train;

const REQUESTS: usize = 40;

fn serve(
    name: &str,
    plan: &CompiledPlan<'_>,
    pipe: &layermerge::pipeline::Pipeline,
) -> anyhow::Result<(f64, f64, f64, f32)> {
    // warm-up
    for i in 0..3 {
        let b = pipe.gen.batch(train::STREAM_EVAL, i);
        if let layermerge::model::Batch::Classify { x, .. } = &b {
            plan.forward(x, None)?;
        }
    }
    let mut lat = Vec::with_capacity(REQUESTS);
    let mut correct = 0.0f32;
    let t0 = Instant::now();
    for i in 0..REQUESTS {
        let b = pipe.gen.batch(train::STREAM_EVAL, i as u64);
        if let layermerge::model::Batch::Classify { x, y } = &b {
            let t = Instant::now();
            let logits = plan.forward(x, None)?;
            lat.push(t.elapsed().as_secs_f64() * 1e3);
            correct += host_accuracy(&logits, y);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p95 = lat[(lat.len() as f64 * 0.95) as usize];
    let imgs_per_s = (REQUESTS * pipe.model.spec.batch) as f64 / wall;
    println!(
        "{name:<28} p50 {p50:>7.2}ms  p95 {p95:>7.2}ms  {imgs_per_s:>8.0} img/s  acc {:.1}%",
        correct / REQUESTS as f32 * 100.0
    );
    Ok((p50, p95, imgs_per_s, correct / REQUESTS as f32))
}

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(std::path::Path::new("artifacts"),
                       std::env::current_dir()?, PipelineCfg::default())?;
    let mut pipe = ctx.pipeline("mnv2ish-1.0")?;

    println!("serving {} batched requests (batch {})\n", REQUESTS, pipe.model.spec.batch);
    let orig = Plan::original(&pipe.model.spec, &pipe.pretrained)?;
    let orig_cp = orig.compile(&pipe.model.rt, &ctx.man, Format::Fused)?;
    let (p50_o, _, thr_o, _) = serve("original mnv2ish-1.0", &orig_cp, &pipe)?;

    for budget in [0.65, 0.5] {
        let c = pipe.run(Method::LayerMerge, budget)?;
        let plan = Plan::from_solution(
            &pipe.model.spec, &c.finetuned, &c.solution.a, &c.solution.c,
            &c.solution.spans,
        )?;
        let cp = plan.compile(&pipe.model.rt, &ctx.man, Format::Fused)?;
        let (p50, _, thr, _) =
            serve(&format!("LayerMerge-{:.0}%", budget * 100.0), &cp, &pipe)?;
        println!(
            "  -> speedup p50 {:.2}x, throughput {:.2}x, depth {} -> {}\n",
            p50_o / p50, thr / thr_o, pipe.model.spec.len(), cp.depth(),
        );
    }
    Ok(())
}
