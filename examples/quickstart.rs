//! Quickstart — compress one model with LayerMerge in a few lines.
//!
//! ```bash
//! make artifacts                       # once: AOT-lower the gated models
//! cargo run --release --example quickstart
//! ```
//!
//! Fast mode (analytical latency + short schedules) keeps this under a
//! couple of minutes; drop `LM_FAST` for measured latencies.

use layermerge::experiments::Ctx;
use layermerge::pipeline::{Method, PipelineCfg};

fn main() -> anyhow::Result<()> {
    std::env::set_var("LM_FAST", "1"); // quickstart: fast mode
    let ctx = Ctx::new(
        std::path::Path::new("artifacts"),
        std::env::current_dir()?,
        PipelineCfg::default(),
    )?;

    // 1. load + pretrain (cached across runs) the ResNet-34 analogue
    let mut pipe = ctx.pipeline("resnetish")?;
    println!(
        "original: {} conv layers, eval acc {:.1}%, latency {:.2} ms",
        pipe.model.spec.len(),
        pipe.orig_metric * 100.0,
        pipe.orig_lat_eager
    );

    // 2. build the T/I lookup tables (Sec. 3.2) and solve Algorithm 1
    //    for 65% of the original latency
    let sol = pipe.solve(Method::LayerMerge, 0.65)?;
    println!("solution: {}", sol.summary());

    // 3. fine-tune the pruned network, merge (Algorithm 2), deploy
    let c = pipe.run(Method::LayerMerge, 0.65)?;
    println!(
        "compressed: depth {} -> {}, acc {:.1}% (pruned {:.1}%), \
         eager {:.2} ms ({:.2}x), fused {:.2} ms ({:.2}x)",
        pipe.model.spec.len(),
        c.depth,
        c.merged_metric * 100.0,
        c.pruned_metric * 100.0,
        c.lat_eager_ms,
        pipe.orig_lat_eager / c.lat_eager_ms,
        c.lat_fused_ms,
        pipe.orig_lat_fused / c.lat_fused_ms,
    );
    Ok(())
}
